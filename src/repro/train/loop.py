"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
simulated failures (the single-process stand-ins for pod-level faults —
DESIGN.md §11 documents the multi-host mapping).

* restart: on startup, restore the latest checkpoint if present and resume
  at its step; the data pipeline is a pure function of step (deterministic
  skip), so no data state is saved.
* straggler mitigation: per-step wall times feed an EWMA; steps slower than
  ``straggler_factor``× the EWMA are logged as stragglers (on a real pod
  this signal drives hot-spare swap; here it drives the log + metrics).
* simulated failure: ``fail_at_step`` raises mid-run — tests restart the
  loop and assert bit-exact continuation vs an uninterrupted run.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.models import api
from repro.train import optim, step as step_mod


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 50
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    async_ckpt: bool = True
    fail_at_step: Optional[int] = None  # simulate a node failure
    straggler_factor: float = 3.0
    log_every: int = 10
    microbatches: int = 1
    grad_sync: str = "xla"  # xla | butterfly | rabenseifner | all_to_all
    fanout: int = 2
    lr_kw: Optional[Dict] = None


class SimulatedFailure(RuntimeError):
    pass


def train(
    cfg: ModelConfig,
    batch_size: int,
    seq_len: int,
    loop: LoopConfig = LoopConfig(),
    *,
    mesh=None,
    rules=None,
    seed: int = 0,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Dict:
    """Returns final metrics dict (params under "params" etc.)."""
    opt = optim.get(cfg.optimizer)
    data = SyntheticLM(cfg, batch_size, seq_len)
    if loop.grad_sync == "xla":
        fn = step_mod.build_train_step(
            cfg, mesh=mesh, rules=rules, microbatches=loop.microbatches,
            lr_kw=loop.lr_kw,
        )
    else:
        fn = step_mod.build_train_step_butterfly(
            cfg, mesh, rules, method=loop.grad_sync, fanout=loop.fanout,
            microbatches=loop.microbatches, lr_kw=loop.lr_kw,
        )
    jfn = jax.jit(fn, donate_argnums=(0, 1))

    start = 0
    params = opt_state = None
    if loop.ckpt_dir and ckpt.latest_step(loop.ckpt_dir) is not None:
        template_p = api.init_params(cfg, jax.random.PRNGKey(seed))
        template_o = opt.init(template_p)
        start, trees = ckpt.restore(
            loop.ckpt_dir, {"params": template_p, "opt_state": template_o}
        )
        params, opt_state = trees["params"], trees["opt_state"]
        print(f"[restart] resumed from step {start}")
    if params is None:
        params = api.init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = opt.init(params)

    ewma = None
    losses: List[float] = []
    pending = None
    for step in range(start, loop.n_steps):
        if loop.fail_at_step is not None and step == loop.fail_at_step:
            raise SimulatedFailure(f"simulated node failure at step {step}")
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch_at(step).items()}
        t0 = time.time()
        params, opt_state, metrics = jfn(
            params, opt_state, batch, jax.numpy.int32(step)
        )
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        straggler = step > start + 2 and dt > loop.straggler_factor * ewma
        losses.append(loss)
        if on_metrics:
            on_metrics(step, {**{k: float(v) for k, v in metrics.items()},
                              "step_time": dt, "straggler": straggler})
        if straggler:
            print(f"[straggler] step {step}: {dt:.2f}s vs ewma {ewma:.2f}s")
        if step % loop.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} ({dt:.2f}s)")
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            if pending is not None:
                pending.join()  # one in-flight async save at a time
            pending = ckpt.save(
                loop.ckpt_dir, step + 1,
                {"params": params, "opt_state": opt_state},
                async_=loop.async_ckpt, meta={"arch": cfg.name},
            )
    if pending is not None:
        pending.join()
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "final_step": loop.n_steps}
