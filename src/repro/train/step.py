"""Train-step builders.

Two distribution paths:

* ``build_train_step`` — GSPMD: shardings come from the params/batch
  in_shardings; XLA schedules the gradient all-reduce.  Used by the 40-cell
  dry-run (the roofline baseline).
* ``build_train_step_butterfly`` — the paper's communication pattern as a
  first-class gradient-sync backend: a partial-manual ``shard_map`` over the
  data axes runs the per-shard backward, then
  :func:`repro.core.collectives.tree_sync` merges gradients with the
  butterfly network (``method`` ∈ butterfly | rabenseifner | all_to_all |
  xla_psum, ``fanout`` knob).  The model axis stays auto, so tensor
  parallelism inside is still GSPMD.  Requires params replicated over data
  (no FSDP) — asserted.

Optional ``microbatches`` folds a ``lax.scan`` gradient accumulation inside
the step (activation memory / global-batch decoupling).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import collectives
from repro.dist.sharding import MeshRules
from repro.models import api
from repro.train import optim


def _split_batch(batch: Dict, n: int) -> Dict:
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _grads_of(loss_fn, params, batch, microbatches: int,
              accum_dtype=jnp.float32):
    if microbatches <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)
    mb = _split_batch(batch, microbatches)

    def acc_fn(carry, b):
        l, g = jax.value_and_grad(loss_fn)(params, b)
        g = jax.tree.map(lambda a, c: a.astype(c.dtype), g, carry[1])
        return (carry[0] + l, jax.tree.map(jnp.add, carry[1], g)), None

    zero = (jnp.float32(0),
            jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params))
    (loss, grads), _ = lax.scan(acc_fn, zero, mb)
    inv = 1.0 / microbatches
    return loss * inv, jax.tree.map(lambda g: (g.astype(jnp.float32) * inv
                                               ).astype(g.dtype), grads)


def build_train_step(
    cfg: ModelConfig,
    *,
    mesh=None,
    rules: Optional[MeshRules] = None,
    microbatches: int = 1,
    clip_norm: float = 1.0,
    lr_kw: Optional[Dict] = None,
):
    """GSPMD train step: (params, opt_state, batch, step_idx) -> ..."""
    loss_fn = api.train_loss_fn(cfg, rules, mesh)
    opt = optim.get(cfg.optimizer)
    lr_kw = lr_kw or {}

    accum = jnp.dtype(cfg.grad_accum_dtype)

    def step(params, opt_state, batch, step_idx):
        loss, grads = _grads_of(loss_fn, params, batch, microbatches, accum)
        grads, gnorm = optim.clip_by_global_norm(grads, clip_norm)
        lr = optim.cosine_lr(step_idx, **lr_kw)
        params, opt_state = opt.apply(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return step


def build_train_step_butterfly(
    cfg: ModelConfig,
    mesh,
    rules: MeshRules,
    *,
    method: str = "butterfly",
    fanout: int = 2,
    microbatches: int = 1,
    clip_norm: float = 1.0,
    compress: Optional[str] = None,  # None | "int8" (error-feedback handled by caller)
    lr_kw: Optional[Dict] = None,
):
    """Paper-pattern gradient sync (DESIGN.md §7)."""
    assert not rules.fsdp, "butterfly grad-sync path requires non-FSDP params"
    axes = rules.batch
    # inner model: no batch-axis constraints (we're manual over those axes)
    inner_rules = MeshRules(batch=(), model=rules.model, fsdp=())
    loss_fn = api.train_loss_fn(cfg, None, None)
    opt = optim.get(cfg.optimizer)
    lr_kw = lr_kw or {}

    accum = jnp.dtype(cfg.grad_accum_dtype)

    def inner(params, opt_state, batch, step_idx):
        loss, grads = _grads_of(loss_fn, params, batch, microbatches, accum)
        if compress == "int8":
            grads = collectives.tree_sync_int8(grads, axes, method=method, fanout=fanout)
        else:
            grads = collectives.tree_sync(grads, axes, method=method, fanout=fanout)
        loss = lax.pmean(loss, axes)
        grads, gnorm = optim.clip_by_global_norm(grads, clip_norm)
        lr = optim.cosine_lr(step_idx, **lr_kw)
        params, opt_state = opt.apply(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    bspec = P(axes if len(axes) > 1 else axes[0])
    step = jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P(), bspec, P()),
        out_specs=(P(), P(), P()),
        axis_names=set(axes),
        check_vma=False,
    )
    return step
