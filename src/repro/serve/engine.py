"""Batched serving: prefill + decode loop with a static KV cache.

The engine allocates the cache at ``max_len`` up front (the paper's
tight-memory-bound philosophy applied to serving: no dynamic allocation in
the decode loop), prefilling writes ``[0, prompt)``, decode appends one
token per step under ``jit``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


def pad_cache(cache, max_len: int):
    """Grow the SELF-attention KV seq axis (rank-5: L,B,S,H,D) to max_len.

    Path-aware: SSM states and whisper's cross-attention KV must NOT be
    padded (cross attention is unmasked — zero keys would perturb the
    softmax; SSM caches are recurrent state, not sequences)."""

    def grow(path, x):
        keys = [str(getattr(p, "key", "")) for p in path]
        if "mamba" in keys or "cross" in keys:
            return x
        # KV layout is (..., S, Hk, D): seq axis is always ndim-3
        # (rank 5 for flat layer stacks, rank 6 for period groups).
        ax = x.ndim - 3
        if keys[-1] in ("k", "v") and x.ndim >= 5 and x.shape[ax] < max_len:
            pad = [(0, 0)] * x.ndim
            pad[ax] = (0, max_len - x.shape[ax])
            return jnp.pad(x, pad)
        return x

    return jax.tree_util.tree_map_with_path(grow, cache)


def prepare_decode_cache(cfg: ModelConfig, cache, pos: int, max_len: int):
    """Pad prefill caches for decode; under ``cfg.ring_local_cache``,
    convert sliding-window layers to the ring layout (§Perf hillclimb 2)."""
    from repro.models import layers, lm

    if not cfg.ring_local_cache or cfg.local_window == 0:
        return pad_cache(cache, max_len)
    w = cfg.local_window
    per = cfg.locals_per_global + 1
    ring2 = jax.vmap(jax.vmap(lambda x: layers.to_ring(x, pos, w)))
    ring1 = jax.vmap(lambda x: layers.to_ring(x, pos, w))
    out = {}
    for name, gc in cache.items():
        kinds = {g[0]: g[2] for g in lm.layer_groups(cfg)}
        kind = kinds.get(name)
        if kind == "attn_period":
            li = [j for j in range(per) if j != cfg.locals_per_global]
            out[name] = {
                "local": {c: ring2(gc[c][:, li]) for c in ("k", "v")},
                "global": {
                    c: pad_cache(
                        {"k": gc[c][:, cfg.locals_per_global : cfg.locals_per_global + 1]},
                        max_len)["k"]
                    for c in ("k", "v")
                },
            }
        elif kind == "attn_local":
            out[name] = {c: ring1(gc[c]) for c in ("k", "v")}
        else:
            out[name] = pad_cache({"x": gc}, max_len)["x"]
    return out


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits (B, V) -> token ids (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray  # (B, n_new)
    steps: int


def generate(
    cfg: ModelConfig,
    params,
    prompts: jax.Array,  # (B, L_prompt) int32
    n_new: int,
    *,
    extra_inputs: Optional[Dict] = None,  # frames / patches for audio / vlm
    temperature: float = 0.0,
    seed: int = 0,
    rules=None,
    mesh=None,
) -> GenerateResult:
    """Prefill the prompts then decode ``n_new`` tokens (greedy or sampled)."""
    b, lp = prompts.shape
    extra = extra_inputs or {}
    prefill = jax.jit(api.prefill_fn(cfg, rules, mesh))
    decode = jax.jit(api.decode_fn(cfg, rules, mesh), donate_argnums=(1,))
    inputs = {"tokens": prompts, **extra}
    logits, cache, pos = prefill(params, inputs)
    prefix = cfg.n_patches if cfg.family == "vlm" else 0
    cache = prepare_decode_cache(cfg, cache, lp + prefix, lp + prefix + n_new)
    rng = jax.random.PRNGKey(seed)
    out = []
    tok = sample(logits, rng, temperature=temperature)
    out.append(tok)
    for i in range(n_new - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, cache, tok[:, None], pos + i)
        tok = sample(logits, k, temperature=temperature)
        out.append(tok)
    return GenerateResult(tokens=np.stack([np.asarray(t) for t in out], 1),
                          steps=n_new)
