"""Graph-analytics subsystem on the butterfly sync (DESIGN.md §13).

* :mod:`repro.analytics.msbfs` — bit-parallel multi-source BFS: B searches
  per wave, one bit-lane per root, phase 2 reuses the butterfly collectives
  unchanged.
* :mod:`repro.analytics.measures` — closeness centrality, reachability
  counts, connected components, all driven by MS-BFS waves.
* :mod:`repro.analytics.engine` — batched query engine: packs root streams
  into fixed-width waves against a cached compiled program; also serves
  the §14 weighted traversals (``sssp``, ``betweenness``) from the same
  placed arrays and program cache.
"""

from repro.analytics.msbfs import build_msbfs_fn, multi_source_bfs  # noqa: F401
from repro.analytics.measures import (  # noqa: F401
    closeness_centrality,
    connected_components,
    reachability_counts,
)
from repro.analytics.engine import BFSQueryEngine  # noqa: F401
