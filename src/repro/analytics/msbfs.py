"""Bit-parallel multi-source BFS (MS-BFS) on the butterfly sync (DESIGN.md §13).

One wave runs up to ``B`` breadth-first searches concurrently, one BIT-LANE
per root: the wave frontier is lane-packed ``uint32[n_rows, B_words]``
(``B_words = ceil(B/32)``) where row ``v`` is vertex ``v`` and bit ``b`` of
lane-word ``b >> 5`` says "search ``b`` has ``v`` in its frontier" — the
Then et al. *The More the Merrier* layout, distributed.

Why this rides the butterfly for free: the phase-2 sync at low frontier
density is LATENCY-bound — ``log_f(P)`` rounds of small messages — and the
round count is independent of how many searches share the words.  Packing
32 lanes into the same exchange multiplies the effective traversal rate at
near-zero extra sync cost (Buluç & Madduri; Pan, Pearce & Owens — see
PAPERS.md).

Phase 1 reuses :func:`repro.core.bfs._expand_push` / ``_expand_pull`` with
``lanes=True`` (the push/pull machinery generalized over the lane axis);
phase 2 reuses ``collectives.butterfly_or`` / ``_sparse`` / ``_adaptive``
UNCHANGED on the flattened word buffer.  The whole B-search wave compiles to
ONE XLA program: ``jit(shard_map(lax.while_loop))``.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import frontier as fr
from repro.core import loop
from repro.core.bfs import (
    INF,
    BFSConfig,
    _expand_pull,
    _expand_push,
    _sync_frontier,
    graph_array_keys,
    place_arrays,
)
from repro.graph.partition import PartitionedGraph

LANE_BITS = fr.WORD_BITS


def lane_words(n_lanes: int) -> int:
    """Words per row: ceil(B/32)."""
    return (n_lanes + LANE_BITS - 1) // LANE_BITS


def wave_rows(pg: PartitionedGraph, *, lane_pad: int = 128) -> int:
    """Vertex rows of the wave buffer: the whole graph plus one device
    window of slack (every device dynamic-slices its aligned
    ``[v_start, v_start + vmax)`` rows without clamping), lane-padded."""
    rows = pg.n + pg.vmax
    return (rows + lane_pad - 1) // lane_pad * lane_pad


def build_msbfs_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: BFSConfig,
    n_lanes: int, *, trace: bool = False, trace_levels=None,
):
    """Compile-ready B-lane multi-source BFS.

    Returns ``run(arrays, roots)`` where ``arrays`` is the SAME placed pytree
    the single-source BFS consumes and ``roots`` a replicated
    ``int32[n_lanes]`` (``-1`` = inactive lane; duplicates allowed).  Output:

    * ``d_owned int32[P, vmax, n_lanes]`` — per-device owned distances, one
      column per lane (INF for unreached / inactive lanes),
    * ``levels int32[P]`` — wave depth (max over lanes, all lanes step
      levels in lock-step),
    * ``scanned float32[P]`` — edges examined, summed over lanes (honest
      aggregate TEPS, paper Sec. 2).

    ``trace=True`` appends the §18 flight-recorder buffer
    ``int32[P, trace_levels, TRACE_COLS]`` (stats over the FLATTENED
    lane-word buffer the sync exchanges; POP/CHANGED aggregate over all
    lanes).  ``trace=False`` stages the exact uninstrumented program.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if cfg.use_pallas:
        raise NotImplementedError(
            "use_pallas=True is single-source only; MS-BFS uses the XLA path"
        )
    bw = lane_words(n_lanes)
    n_rows = wave_rows(pg)
    vmax = pg.vmax
    max_levels = cfg.max_levels if cfg.max_levels is not None else pg.n
    spec = P(cfg.axes if len(cfg.axes) > 1 else cfg.axes[0])
    if trace:
        from repro.core import flightrec

        t_levels = flightrec.resolve_trace_levels(trace_levels, max_levels)

    def body(arrays, roots):
        arrays = jax.tree.map(lambda a: a[0], arrays)
        v_start = arrays["v_start"]
        v_count = arrays["v_count"]
        vown_ids = jnp.arange(vmax, dtype=jnp.int32)
        owned_mask = vown_ids < v_count

        lane_ids = jnp.arange(n_lanes, dtype=jnp.int32)
        lane_active = roots >= 0
        seed_rows = jnp.where(lane_active, roots, 0).astype(jnp.int32)
        # one-hot lane masks: row per lane, bit per lane; OR-scattered so
        # duplicate roots compose (two lanes may share a seed vertex).
        onehot = (
            jnp.arange(bw * LANE_BITS, dtype=jnp.int32)[None, :] == lane_ids[:, None]
        ) & lane_active[:, None]
        seen = fr.scatter_or_lanes(n_rows, seed_rows, fr.lane_pack(onehot))
        frontier = seen

        def owned_lanes(buf):
            win = lax.dynamic_slice(buf, (v_start, 0), (vmax, bw))
            return fr.lane_unpack(win)[:, :n_lanes] & owned_mask[:, None]

        d_owned = jnp.where(owned_lanes(seen), 0, INF)

        if cfg.mode == "bottom_up":
            init_dir = jnp.array(True)
        else:
            init_dir = jnp.array(False)  # False == push

        def cond(state):
            frontier, seen, d_owned, level, scanned, pull = state[:6]
            return (fr.popcount(frontier) > 0) & (level < max_levels)

        def step(state):
            frontier, seen, d_owned, level, scanned, pull = state[:6]

            # -- Phase 1: lane-parallel traversal ------------------------
            def do_push(_):
                return _expand_push(arrays, frontier, n_rows, False, lanes=True)

            def do_pull(_):
                return _expand_pull(
                    arrays, frontier, seen, n_rows, False, lanes=True
                )

            if cfg.mode == "top_down":
                gq = do_push(None)
            elif cfg.mode == "bottom_up":
                gq = do_pull(None)
            else:
                gq = lax.cond(pull, do_pull, do_push, None)

            # edges examined this level, summed over ACTIVE lanes (inactive
            # lanes would otherwise count every vertex as unvisited):
            owned_front = owned_lanes(frontier)
            m_f = (arrays["deg_out"][:, None] * owned_front).sum()
            owned_unvis = (
                ~fr.lane_unpack(
                    lax.dynamic_slice(seen, (v_start, 0), (vmax, bw))
                )[:, :n_lanes]
                & owned_mask[:, None]
                & lane_active[None, :]
            )
            m_u = (arrays["deg_out"][:, None] * owned_unvis).sum()
            if cfg.mode == "bottom_up":
                lvl_scanned = m_u
            elif cfg.mode == "top_down":
                lvl_scanned = m_f
            else:
                lvl_scanned = jnp.where(pull, m_u, m_f)

            # -- Phase 2: butterfly sync, UNCHANGED on the flat buffer ---
            if trace:
                t_words, t_branch, t_shipped = flightrec.or_sync_stats(
                    gq.reshape(-1), cfg
                )
            merged = _sync_frontier(gq.reshape(-1), cfg).reshape(n_rows, bw)

            # -- Per-lane enqueue-if-new + level capture -----------------
            new = merged & ~seen
            seen = seen | new
            d_owned = jnp.where(owned_lanes(new), level + 1, d_owned)

            # -- Direction-optimizing switch, wave-aggregated ------------
            if cfg.mode == "direction_optimizing":
                g_mf = lax.psum(m_f, cfg.axes)
                g_mu = lax.psum(m_u, cfg.axes)
                n_f = fr.popcount(new)
                active_count = jnp.maximum(
                    lane_active.sum(dtype=jnp.int32), 1
                )
                go_pull = g_mf.astype(jnp.float32) > (
                    g_mu.astype(jnp.float32) / cfg.alpha
                )
                go_push = n_f.astype(jnp.float32) < (
                    active_count * pg.n / cfg.beta
                )
                pull = jnp.where(pull, ~go_push, go_pull)

            out = (
                new,
                seen,
                d_owned,
                level + 1,
                scanned + lvl_scanned.astype(jnp.float32),
                pull,
            )
            if not trace:
                return out, None
            if cfg.mode == "top_down":
                direction = jnp.int32(0)
            elif cfg.mode == "bottom_up":
                direction = jnp.int32(1)
            else:
                direction = state[5].astype(jnp.int32)
            row = flightrec.trace_row(
                level, t_words, fr.popcount(new), direction, t_branch,
                t_shipped, jnp.count_nonzero(new).astype(jnp.int32),
            )
            return out, (level, row)

        init = (
            frontier,
            seen,
            d_owned,
            jnp.int32(0),
            jnp.float32(0),
            init_dir,
        )
        state = loop.traced_while(
            cond, step, init, trace=trace,
            trace_levels=t_levels if trace else None,
        )
        frontier, seen, d_owned, level, scanned, _ = state[:6]
        total_scanned = lax.psum(scanned, cfg.axes)
        out = (d_owned[None], level[None], total_scanned[None])
        if trace:
            out = out + (state[6][None],)
        return out

    return loop.jit_shard(body, mesh, graph_array_keys(pg), spec, trace=trace)


def assemble_distances(
    pg: PartitionedGraph, d_owned: np.ndarray, n_lanes: int
) -> np.ndarray:
    """``d_owned [P, vmax, B]`` -> global ``int64[B, n]`` distance matrix
    (row per search lane, INT32_MAX sentinel for unreached)."""
    d_owned = np.asarray(d_owned)
    dist = np.full((n_lanes, pg.n), np.iinfo(np.int32).max, dtype=np.int64)
    for i in range(pg.p):
        s, c = int(pg.v_start[i]), int(pg.v_count[i])
        dist[:, s : s + c] = d_owned[i, :c, :].T
    return dist


def multi_source_bfs(
    pg: PartitionedGraph,
    mesh: jax.sharding.Mesh,
    roots: Sequence[int],
    cfg: BFSConfig = BFSConfig(),
) -> Tuple[np.ndarray, int, float]:
    """End-to-end helper: one wave over ``roots`` (one lane per root).

    Returns ``(dist int64[B, n], levels, scanned)``; ``dist[b]`` matches
    ``bfs_reference(g, roots[b])`` exactly.  ``-1`` marks an inactive lane
    (all-INF row); any other out-of-range root raises.
    """
    roots = np.asarray(roots, dtype=np.int32)
    if roots.ndim != 1 or roots.size < 1:
        raise ValueError("roots must be a non-empty 1-D sequence")
    if np.any((roots < -1) | (roots >= pg.n)):
        raise ValueError(f"root out of range (n={pg.n}, -1=inactive): {roots}")
    arrays = place_arrays(pg, mesh, cfg.axes)
    fn = build_msbfs_fn(pg, mesh, cfg, int(roots.size))
    d_owned, levels, scanned = fn(arrays, jnp.asarray(roots))
    dist = assemble_distances(pg, d_owned, int(roots.size))
    return dist, int(np.max(levels)), float(np.asarray(scanned)[0])
