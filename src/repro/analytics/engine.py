"""Batched traversal query engine (DESIGN.md §13/§14).

The serving philosophy of ``serve/engine.py`` applied to traversal: all
allocation and compilation happen ONCE, up front — graph arrays are placed
on the mesh at construction, and one compiled program per
``(graph, mesh, algo, config, lanes)`` is cached module-wide.  Query
streams are then packed into fixed-width waves (pad lanes carry root ``-1``
and cost nothing: their bit-lanes never activate), so every wave reuses the
same compiled program with the same static shapes — no recompiles, no
dynamic allocation on the query path.

Four query families share the placed arrays and the cache:

* ``query``          — BFS distances, B bit-lanes per wave (§13),
* ``sssp``           — weighted distances, one butterfly-min program reused
                       across the root stream (§14),
* ``betweenness``    — Brandes dependency waves, B lanes per wave,
                       accumulated across waves (§14),
* ``vertex_program`` — §19 gather-apply-scatter analytics (pagerank / cc /
                       tri / kcore), one compiled program per algo+config,
                       warm-startable via ``arg`` (the §16 re-push path).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import programs
from repro.analytics import msbfs
from repro.core import metrics as metrics_mod
from repro.core.bfs import BFSConfig, place_arrays
from repro.core.devlock import device_lock
from repro.graph.partition import PartitionedGraph
from repro.traversal import bc as bc_mod
from repro.traversal import sssp as sssp_mod
from repro.traversal.sssp import SSSPConfig

# Registry-backed engine observability (DESIGN.md §20).  Host-side only:
# none of these touch staged programs, so lowered HLO is byte-identical
# with metrics enabled or absent (tests/test_metrics.py proves it).
_REG = metrics_mod.default_registry()
_CACHE_EVENTS = _REG.counter(
    "engine_program_cache_total",
    "compiled-program cache events (hit / miss / evict)", ("event",))
_BUILDS = _REG.counter(
    "engine_program_builds_total",
    "program constructions on cache miss (the compile events), by algo",
    ("algo",))
_BUILD_SECONDS = _REG.histogram(
    "engine_program_build_seconds", "wall time of each program build",
    buckets=(0.001, 0.01, 0.1, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0))
_WAVES = _REG.counter(
    "engine_waves_total", "compiled-program invocations, by algo",
    ("algo",))
_DEDUPED = _REG.counter(
    "engine_deduped_roots_total",
    "duplicate roots folded out of waves before lane packing")

# Compiled-program cache: (graph identity, mesh identity, algo, cfg, lanes)
# -> (fn, pg, mesh).  Configs are frozen dataclasses, so they hash by value;
# graphs and meshes hash by identity (re-partitioning a graph is a new
# program).  Each entry keeps a STRONG reference to its graph and mesh so a
# live key's id() can never be recycled onto a different object (id-reuse
# after GC would otherwise alias a stale program).  Bounded LRU — hits
# refresh recency, eviction drops the coldest program — so a long-lived
# service process churning graphs/configs keeps its hot programs while dead
# graphs + executables don't accumulate forever.
_PROGRAM_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_PROGRAM_CACHE_MAX = 32


_REG.gauge(
    "engine_program_cache_size", "live entries in the program cache"
).set_function(lambda: len(_PROGRAM_CACHE))


def _cached(pg, mesh, key: Tuple, build: Callable[[], object]):
    entry = _PROGRAM_CACHE.get(key)
    if entry is not None and entry[1] is pg and entry[2] is mesh:
        _PROGRAM_CACHE.move_to_end(key)
        _CACHE_EVENTS.inc(event="hit")
        return entry[0]
    _CACHE_EVENTS.inc(event="miss")
    t0 = time.perf_counter()
    fn = build()
    _BUILD_SECONDS.observe(time.perf_counter() - t0)
    _BUILDS.inc(algo=str(key[2]) if len(key) > 2 else "?")
    while len(_PROGRAM_CACHE) >= _PROGRAM_CACHE_MAX:
        _PROGRAM_CACHE.popitem(last=False)
        _CACHE_EVENTS.inc(event="evict")
    _PROGRAM_CACHE[key] = (fn, pg, mesh)
    return fn


def compiled_wave_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: BFSConfig, lanes: int
):
    """The cached ``jit(shard_map(...))`` MS-BFS program for this key."""
    return _cached(
        pg, mesh, (id(pg), id(mesh), "bfs", cfg, lanes),
        lambda: msbfs.build_msbfs_fn(pg, mesh, cfg, lanes),
    )


def compiled_sssp_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: SSSPConfig
):
    """The cached distributed-SSSP program for this key."""
    return _cached(
        pg, mesh, (id(pg), id(mesh), "sssp", cfg),
        lambda: sssp_mod.build_sssp_fn(pg, mesh, cfg),
    )


def compiled_bc_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: BFSConfig, lanes: int
):
    """The cached betweenness-centrality wave program for this key."""
    return _cached(
        pg, mesh, (id(pg), id(mesh), "bc", cfg, lanes),
        lambda: bc_mod.build_bc_fn(pg, mesh, cfg, lanes),
    )


def compiled_program_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, algo: str,
    cfg: "programs.ProgramConfig",
):
    """The cached §19 vertex-program executable for this key (warm starts
    reuse it — only the operand differs)."""
    prog = programs.by_name(algo)
    return _cached(
        pg, mesh, (id(pg), id(mesh), "vp:" + algo, cfg),
        lambda: programs.build_program_fn(pg, mesh, prog, cfg),
    )


@dataclasses.dataclass
class EngineStats:
    queries: int = 0
    waves: int = 0
    deduped_roots: int = 0  # duplicate roots folded out of waves (§15)
    scanned_edges: float = 0.0  # aggregate over lanes, honest TEPS numerator
    max_levels: int = 0
    sssp_queries: int = 0
    relaxed_edges: float = 0.0  # SSSP relaxation analogue of scanned_edges
    bc_sources: int = 0
    program_runs: int = 0  # §19 vertex-program executions
    program_iters: int = 0  # gather/sync/apply rounds across those runs
    program_edges: float = 0.0  # edges examined by vertex programs


class BFSQueryEngine:
    """Accepts streams of root queries, answers with distance vectors.

    ``lanes`` is the wave width (bit-lanes per wave; 32 fills one uint32
    lane-word).  Queries are packed greedily: ``ceil(len(roots)/lanes)``
    waves per batch, each one compiled-program call.
    """

    def __init__(
        self,
        pg: PartitionedGraph,
        mesh: jax.sharding.Mesh,
        cfg: BFSConfig = BFSConfig(),
        *,
        lanes: int = 32,
    ):
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.pg = pg
        self.mesh = mesh
        self.cfg = cfg
        self.lanes = lanes
        self.stats = EngineStats()
        self._arrays = place_arrays(pg, mesh, cfg.axes)
        self._fn = compiled_wave_fn(pg, mesh, cfg, lanes)

    def refresh_arrays(self) -> None:
        """Re-place the partition arrays after an IN-PLACE host mutation
        (``dynamic.delta.apply_update_to_partition``, DESIGN.md §16).  The
        partition object — hence every compiled program keyed on its
        identity — is unchanged: shapes are static, only values moved."""
        self._arrays = place_arrays(self.pg, self.mesh, self.cfg.axes)

    def _run_wave(self, roots: np.ndarray) -> np.ndarray:
        padded = np.full(self.lanes, -1, dtype=np.int32)
        padded[: roots.size] = roots
        with device_lock(self.mesh):
            d_owned, levels, scanned = self._fn(
                self._arrays, jnp.asarray(padded)
            )
            # materialize INSIDE the lock: ops on the lazy outputs (even
            # np.max) dispatch fresh device programs, which must not
            # overlap another engine's collectives on shared devices
            d_owned, levels, scanned = (
                np.asarray(d_owned), np.asarray(levels), np.asarray(scanned)
            )
        self.stats.waves += 1
        _WAVES.inc(algo="bfs")
        self.stats.scanned_edges += float(np.asarray(scanned)[0])
        self.stats.max_levels = max(self.stats.max_levels, int(np.max(levels)))
        dist = msbfs.assemble_distances(self.pg, d_owned, self.lanes)
        return dist[: roots.size]

    def _checked_ids(self, ids: Sequence[int], what: str) -> np.ndarray:
        """Shared query-path validation: non-empty 1-D int32 vertex ids in
        ``[0, n)`` (pad lanes are an engine-internal detail — callers never
        pass ``-1``)."""
        ids = np.asarray(ids, dtype=np.int32)
        if ids.ndim != 1 or ids.size == 0:
            raise ValueError(f"{what}s must be a non-empty 1-D sequence")
        if np.any((ids < 0) | (ids >= self.pg.n)):
            raise ValueError(f"{what} out of range [0, {self.pg.n}): {ids}")
        return ids

    def query(self, roots: Sequence[int]) -> np.ndarray:
        """Distances for every root: ``int64[len(roots), n]`` (INT32_MAX for
        unreached), in query order.

        Duplicate roots are folded before lane packing — each DISTINCT root
        occupies one lane and every duplicate reads the shared result row —
        so a hot root repeated across a batch burns one lane, not many
        (``stats.deduped_roots`` counts the folds)."""
        roots = self._checked_ids(roots, "root")
        uniq, inverse = np.unique(roots, return_inverse=True)
        out: List[np.ndarray] = []
        for lo in range(0, uniq.size, self.lanes):
            out.append(self._run_wave(uniq[lo : lo + self.lanes]))
        self.stats.queries += int(roots.size)
        self.stats.deduped_roots += int(roots.size - uniq.size)
        _DEDUPED.inc(int(roots.size - uniq.size))
        return np.concatenate(out, axis=0)[inverse]

    def query_one(self, root: int) -> np.ndarray:
        """Single-root convenience: ``int64[n]`` distances."""
        return self.query([root])[0]

    def profile(self, root: int = 0, *, iters: int = 3) -> Dict:
        """§20 cost-model profile: a deep (timed + HLO-reconciled) profile
        of the single-source program from ``root``, plus the static
        analytic-vs-HLO byte reconciliation of every program cached for
        this graph.  Returns ``{"program": ProgramProfile,
        "cache": [CacheEntryReport, ...]}``."""
        from repro.core import profiler

        with device_lock(self.mesh):
            prof = profiler.profile_bfs(
                self.pg, self.mesh, self.cfg, int(root), iters=iters,
                arrays=self._arrays,
            )
            cache = profiler.cache_report(self)
        return {"program": prof, "cache": cache}

    # --- weighted traversals (DESIGN.md §14) ------------------------------

    def _sssp_cfg(self, cfg: Optional[SSSPConfig]) -> SSSPConfig:
        if cfg is not None:
            return cfg
        if self.cfg.sync not in sssp_mod.SYNCS:
            # never silently coerce (PR 2 killed that class of fallbacks):
            # a 'rabenseifner' engine would otherwise measure 'butterfly'
            raise ValueError(
                f"engine sync {self.cfg.sync!r} has no SSSP equivalent "
                f"(expected one of {sssp_mod.SYNCS}); pass an explicit "
                "SSSPConfig"
            )
        return SSSPConfig(
            axes=self.cfg.axes, fanout=self.cfg.fanout, sync=self.cfg.sync,
            sparse_capacity=self.cfg.sparse_capacity,
            density_threshold=self.cfg.density_threshold,
        )

    def sssp(
        self, roots: Sequence[int], cfg: Optional[SSSPConfig] = None
    ) -> np.ndarray:
        """Weighted distances for every root: ``int64[len(roots), n]``
        (:data:`repro.traversal.sssp.UNREACHED` for unreachable), in query
        order.  One compiled butterfly-min program serves the whole stream;
        ``cfg`` defaults to the engine's BFS knobs lifted to
        :class:`SSSPConfig`."""
        roots = self._checked_ids(roots, "root")
        cfg = self._sssp_cfg(cfg)
        fn = compiled_sssp_fn(self.pg, self.mesh, cfg)
        out = np.empty((roots.size, self.pg.n), dtype=np.int64)
        for i, r in enumerate(roots):
            with device_lock(self.mesh):
                d_owned, _, relaxed = fn(self._arrays, jnp.int32(r))
                d_owned, relaxed = np.asarray(d_owned), np.asarray(relaxed)
            out[i] = sssp_mod.assemble_distances(self.pg, d_owned)
            self.stats.relaxed_edges += float(np.asarray(relaxed)[0])
            _WAVES.inc(algo="sssp")
        self.stats.sssp_queries += int(roots.size)
        return out

    def betweenness(self, sources: Sequence[int]) -> np.ndarray:
        """Betweenness centrality accumulated over ``sources``:
        ``float64[n]``.  Sources pack into ``lanes``-wide Brandes waves
        (pad lanes carry ``-1``); one compiled program serves every wave.
        """
        sources = self._checked_ids(sources, "source")
        fn = compiled_bc_fn(self.pg, self.mesh, self.cfg, self.lanes)
        bc = np.zeros(self.pg.n, dtype=np.float64)
        for lo in range(0, sources.size, self.lanes):
            chunk = sources[lo : lo + self.lanes]
            padded = np.full(self.lanes, -1, dtype=np.int32)
            padded[: chunk.size] = chunk
            with device_lock(self.mesh):
                bc_owned, depth, scanned = fn(
                    self._arrays, jnp.asarray(padded)
                )
                bc_owned, depth, scanned = (
                    np.asarray(bc_owned), np.asarray(depth),
                    np.asarray(scanned),
                )
            bc += bc_mod.assemble_bc(self.pg, bc_owned)
            self.stats.waves += 1
            _WAVES.inc(algo="bc")
            self.stats.scanned_edges += float(np.asarray(scanned)[0])
            self.stats.max_levels = max(
                self.stats.max_levels, int(np.max(depth))
            )
        self.stats.bc_sources += int(sources.size)
        return bc

    # --- vertex programs (DESIGN.md §19) ----------------------------------

    def _program_cfg(
        self, cfg: Optional["programs.ProgramConfig"]
    ) -> "programs.ProgramConfig":
        if cfg is not None:
            return cfg
        if self.cfg.sync not in programs.SYNCS:
            # same no-silent-coercion rule as _sssp_cfg: a 'rabenseifner'
            # engine must not quietly measure 'butterfly'
            raise ValueError(
                f"engine sync {self.cfg.sync!r} has no vertex-program "
                f"equivalent (expected one of {programs.SYNCS}); pass an "
                "explicit ProgramConfig"
            )
        return programs.ProgramConfig(
            axes=self.cfg.axes, fanout=self.cfg.fanout, sync=self.cfg.sync,
            sparse_capacity=self.cfg.sparse_capacity,
            density_threshold=self.cfg.density_threshold,
        )

    def vertex_program(
        self,
        algo: str,
        cfg: Optional["programs.ProgramConfig"] = None,
        *,
        arg=None,
    ) -> np.ndarray:
        """Run one §19 vertex program to convergence; returns its global
        result vector (``pagerank``: float64 ranks; ``cc``: int64 min
        labels; ``tri``: int64 per-vertex triangle counts; ``kcore``:
        int64 core numbers).  ``arg`` warm-starts convergence-style
        programs (the §16 re-push seed); ``cfg`` defaults to the engine's
        BFS knobs lifted to :class:`~repro.programs.ProgramConfig`."""
        result, _, _ = self.run_program(algo, cfg, arg=arg)
        return result

    def run_program(
        self,
        algo: str,
        cfg: Optional["programs.ProgramConfig"] = None,
        *,
        arg=None,
    ):
        """:meth:`vertex_program` plus the convergence accounting:
        ``(result, iters, edges_examined)`` — the repair path reads
        ``iters`` for the §16 re-push-vs-recompute ledger."""
        prog = programs.by_name(algo)
        cfg = self._program_cfg(cfg)
        fn = compiled_program_fn(self.pg, self.mesh, algo, cfg)
        if arg is None:
            arg = prog.default_arg(self.pg)
        with device_lock(self.mesh):
            out = fn(self._arrays, arg)
            # materialize INSIDE the lock (same rule as _run_wave)
            out = [np.asarray(o) for o in out]
        iters = int(np.max(out[prog.n_outputs]))
        work = float(out[prog.n_outputs + 1][0])
        self.stats.program_runs += 1
        self.stats.program_iters += iters
        self.stats.program_edges += work
        _WAVES.inc(algo="vp:" + algo)
        return prog.assemble(self.pg, out[0]), iters, work
