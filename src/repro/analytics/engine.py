"""Batched multi-source BFS query engine (DESIGN.md §13).

The serving philosophy of ``serve/engine.py`` applied to traversal: all
allocation and compilation happen ONCE, up front — graph arrays are placed
on the mesh at construction, and one MS-BFS program per
``(graph, BFSConfig, lanes)`` is compiled and cached module-wide.  Query
streams are then packed into fixed-width waves (pad lanes carry root ``-1``
and cost nothing: their bit-lanes never activate), so every wave reuses the
same compiled program with the same static shapes — no recompiles, no
dynamic allocation on the query path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.analytics import msbfs
from repro.core.bfs import BFSConfig, place_arrays
from repro.graph.partition import PartitionedGraph

# Compiled-program cache: (graph identity, mesh identity, cfg, lanes) -> fn.
# BFSConfig is a frozen dataclass, so it hashes by value; graphs and meshes
# hash by identity (re-partitioning a graph is a new program).  Bounded
# FIFO: id-keyed entries are unreachable once the caller drops the graph,
# so an unbounded dict would pin dead graphs + executables forever.
_PROGRAM_CACHE: Dict[Tuple, object] = {}
_PROGRAM_CACHE_MAX = 32


def compiled_wave_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: BFSConfig, lanes: int
):
    """The cached ``jit(shard_map(...))`` MS-BFS program for this key."""
    key = (id(pg), id(mesh), cfg, lanes)
    fn = _PROGRAM_CACHE.get(key)
    if fn is None:
        fn = msbfs.build_msbfs_fn(pg, mesh, cfg, lanes)
        while len(_PROGRAM_CACHE) >= _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.pop(next(iter(_PROGRAM_CACHE)))
        _PROGRAM_CACHE[key] = fn
    return fn


@dataclasses.dataclass
class EngineStats:
    queries: int = 0
    waves: int = 0
    scanned_edges: float = 0.0  # aggregate over lanes, honest TEPS numerator
    max_levels: int = 0


class BFSQueryEngine:
    """Accepts streams of root queries, answers with distance vectors.

    ``lanes`` is the wave width (bit-lanes per wave; 32 fills one uint32
    lane-word).  Queries are packed greedily: ``ceil(len(roots)/lanes)``
    waves per batch, each one compiled-program call.
    """

    def __init__(
        self,
        pg: PartitionedGraph,
        mesh: jax.sharding.Mesh,
        cfg: BFSConfig = BFSConfig(),
        *,
        lanes: int = 32,
    ):
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.pg = pg
        self.mesh = mesh
        self.cfg = cfg
        self.lanes = lanes
        self.stats = EngineStats()
        self._arrays = place_arrays(pg, mesh, cfg.axes)
        self._fn = compiled_wave_fn(pg, mesh, cfg, lanes)

    def _run_wave(self, roots: np.ndarray) -> np.ndarray:
        padded = np.full(self.lanes, -1, dtype=np.int32)
        padded[: roots.size] = roots
        d_owned, levels, scanned = self._fn(self._arrays, jnp.asarray(padded))
        self.stats.waves += 1
        self.stats.scanned_edges += float(np.asarray(scanned)[0])
        self.stats.max_levels = max(self.stats.max_levels, int(np.max(levels)))
        dist = msbfs.assemble_distances(self.pg, d_owned, self.lanes)
        return dist[: roots.size]

    def query(self, roots: Sequence[int]) -> np.ndarray:
        """Distances for every root: ``int64[len(roots), n]`` (INT32_MAX for
        unreached), in query order."""
        roots = np.asarray(roots, dtype=np.int32)
        if roots.ndim != 1 or roots.size == 0:
            raise ValueError("roots must be a non-empty 1-D sequence")
        if np.any((roots < 0) | (roots >= self.pg.n)):
            raise ValueError(f"root out of range [0, {self.pg.n}): {roots}")
        out: List[np.ndarray] = []
        for lo in range(0, roots.size, self.lanes):
            out.append(self._run_wave(roots[lo : lo + self.lanes]))
        self.stats.queries += int(roots.size)
        return np.concatenate(out, axis=0)

    def query_one(self, root: int) -> np.ndarray:
        """Single-root convenience: ``int64[n]`` distances."""
        return self.query([root])[0]
