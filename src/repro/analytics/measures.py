"""Whole-graph analytics on MS-BFS wave outputs (DESIGN.md §13).

Distributed BFS is the building block for graph analytics (Buluç &
Madduri); every measure here consumes the ``int64[B, n]`` distance matrices
produced by :mod:`repro.analytics.msbfs` / the query engine — the traversal
stays on-device and bit-parallel, the reductions are cheap host-side numpy.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.bfs import BFSConfig
from repro.graph.partition import PartitionedGraph

INF32 = np.iinfo(np.int32).max


def reachability_counts(dist: np.ndarray) -> np.ndarray:
    """Vertices reached per search lane (root included): ``int64[B]``."""
    dist = np.asarray(dist)
    return (dist < INF32).sum(axis=1)


def closeness_centrality(
    dist: np.ndarray, *, n: Optional[int] = None, wf_improved: bool = True
) -> np.ndarray:
    """Closeness of each wave root from its distance row: ``float64[B]``.

    ``c(u) = (r - 1) / sum_d`` over the ``r`` reached vertices; with
    ``wf_improved`` the Wasserman–Faust factor ``(r - 1)/(n - 1)`` scales by
    the reachable fraction so scores compare across components (``n``
    defaults to the row width — pass the un-padded vertex count to exclude
    bitmap padding).  Roots reaching nothing score 0.
    """
    dist = np.asarray(dist)
    if n is None:
        n = dist.shape[1]
    finite = dist < INF32
    r = finite.sum(axis=1)
    sum_d = np.where(finite, dist, 0).sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(sum_d > 0, (r - 1) / np.maximum(sum_d, 1), 0.0)
        if wf_improved and n > 1:
            c = c * (r - 1) / (n - 1)
    return c.astype(np.float64)


def connected_components(
    pg: PartitionedGraph,
    mesh,
    cfg: BFSConfig = BFSConfig(),
    *,
    lanes: int = 32,
    engine=None,
) -> np.ndarray:
    """Component labels via lane-seeded wave propagation: ``int64[n]``.

    Each round seeds one MS-BFS wave with up to ``lanes`` still-unlabeled
    vertices; every vertex a lane reaches joins that seed's component (label
    = seed vertex id, smallest seed winning ties — on the undirected graphs
    the ETL produces, reachability IS the component relation, and the
    butterfly OR of the wave is the label-propagation step).  Rounds repeat
    until no vertex is unlabeled: ``ceil(#components / lanes)`` waves total,
    so B lanes cut the sync rounds per graph by ~B over one-seed flooding.
    """
    if engine is None:
        from repro.analytics.engine import BFSQueryEngine

        engine = BFSQueryEngine(pg, mesh, cfg, lanes=lanes)
    labels = np.full(pg.n, -1, dtype=np.int64)
    while True:
        unlabeled = np.flatnonzero(labels < 0)
        if unlabeled.size == 0:
            return labels
        seeds = unlabeled[: engine.lanes]
        dist = engine.query(seeds)
        for b, s in enumerate(seeds):  # ascending seeds: smallest wins
            reached = (dist[b] < INF32) & (labels < 0)
            labels[reached] = s
