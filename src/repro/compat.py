"""Version-compatibility shims for the pinned JAX toolchain.

The codebase is written against the modern public JAX API:

* ``jax.shard_map(..., check_vma=..., axis_names=...)``
* ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
* ``jax.tree.flatten_with_path``

The container pins ``jax==0.4.37`` where those spell differently
(``jax.experimental.shard_map.shard_map`` with ``check_rep``/``auto``, no
``AxisType``, no ``axis_types`` kwarg).  Rather than sprinkle version
branches through every module, this file installs forward-looking aliases
onto the ``jax`` namespace once, at ``repro`` import time.  On a JAX that
already provides the modern names every shim is a no-op, so the package
keeps working unchanged after an upgrade.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.tree
import jax.tree_util


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        return
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        # axis_types is advisory (Auto everywhere here); old JAX has no
        # explicit-sharding mode, so dropping it preserves semantics.
        del axis_types
        return _make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        kwargs = {}
        if axis_names is not None:
            # modern API: axis_names = manually-mapped axes; old API takes
            # the complement as `auto`.
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                kwargs["auto"] = auto
        return _shard_map(f, mesh, in_specs, out_specs,
                          check_rep=check_vma, **kwargs)

    jax.shard_map = shard_map


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of a concrete 1 is evaluated eagerly to the (static) axis
        # size — the documented pre-axis_size idiom.
        total = 1
        names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        for name in names:
            total *= jax.lax.psum(1, name)
        return total

    jax.lax.axis_size = axis_size


def _install_tree_flatten_with_path() -> None:
    if hasattr(jax.tree, "flatten_with_path"):
        return
    jax.tree.flatten_with_path = jax.tree_util.tree_flatten_with_path


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()
    _install_axis_size()
    _install_tree_flatten_with_path()


install()
