"""PageRank as a vertex program (DESIGN.md §19) — the first NON-idempotent
monoid on the sparse butterfly path.

Power iteration in the gather-apply-scatter contract:

* **gather** — each rank scatters ``rank[u] / deg_out[u]`` over its owned
  out-edges into a per-rank CONTRIBUTION buffer (``ADD_F32``), plus its
  owned dangling mass into the slack row ``n`` (riding the same exchange —
  no second collective);
* **sync** — ADD is not idempotent, so the sparse path runs in **delta
  mode** (``ref=None``): each rank ships its own nonzero contribution
  words, identity-padded with exact ``0.0`` no-ops; the butterfly delivers
  each subcube partial exactly once, so sparse/adaptive results are
  **bit-identical** to the dense reduce (the §19 dichotomy, verified by
  ``tests/test_programs.py``);
* **apply** — ``rank' = (1-d)/n + d * (contrib + dangling/n)`` on every
  rank from the replicated merged buffer; convergence when the total L1
  residual drops to ``cfg.tol``.

Warm starts are first-class: ``arg`` is the initial rank vector, so the
§16 mutation path re-pushes from the cached pre-mutation ranks instead of
cold-starting from uniform (:func:`repair_rank_rows`) — same compiled
program, a fraction of the rounds.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import monoid as mono
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph
from repro.programs import core


class PageRankProgram(core.VertexProgram):
    name = "pagerank"
    monoid = mono.ADD_F32

    def init(self, ctx, arg):
        # arg: replicated float32[n_rows] initial ranks (uniform cold start,
        # a cached vector for §16 warm re-push); residual inf => >= 1 round
        return (arg, jnp.float32(jnp.inf))

    def active(self, ctx, state, it):
        return state[1] > jnp.float32(ctx.cfg.tol)

    def gather(self, ctx, state, it):
        rank = state[0]
        a = ctx.arrays
        src, dst = a["edge_src"], a["edge_dst"]
        emask = ctx.edge_mask
        # out-degree of each owned edge's source (locally indexed; real
        # owned edges always have deg_out >= 1 — they carry this edge)
        lidx = jnp.where(emask, src - ctx.v_start, 0)
        deg = jnp.maximum(a["deg_out"][lidx], 1).astype(jnp.float32)
        contrib = jnp.where(emask, rank[src] / deg, jnp.float32(0))
        msg = jnp.zeros((ctx.n_rows,), jnp.float32).at[dst].add(contrib)
        # owned dangling mass rides the exchange in slack row n (outside
        # every owned output window, so it never leaks into results)
        owned_rank = ctx.owned_slice(rank)
        dangle = jnp.where(
            ctx.owned_mask & (a["deg_out"] == 0), owned_rank, 0.0
        ).sum(dtype=jnp.float32)
        msg = msg.at[ctx.n].add(dangle)
        return msg, None, emask.sum(dtype=jnp.float32)

    def apply(self, ctx, state, merged, it):
        rank = state[0]
        n = ctx.n
        d = jnp.float32(ctx.cfg.damping)
        base = (1.0 - d) / n + d * merged[n] / n
        real = jnp.arange(ctx.n_rows, dtype=jnp.int32) < n
        new = jnp.where(real, base + d * merged, jnp.float32(0))
        resid = jnp.abs(new - rank).sum(dtype=jnp.float32)
        return (new, resid)

    def outputs(self, ctx, state):
        return (ctx.owned_slice(state[0]),)

    def metrics(self, ctx, state, merged):
        # POP: residual mass in parts-per-million (int32 trace cell)
        ppm = jnp.minimum(state[1] * 1e6, jnp.float32(2**31 - 1))
        return ppm.astype(jnp.int32), jnp.int32(0)

    def default_max_iters(self, pg: PartitionedGraph) -> int:
        return 200

    def default_arg(self, pg: PartitionedGraph):
        return uniform_ranks(pg)

    def assemble(self, pg: PartitionedGraph, out) -> np.ndarray:
        ranks = np.zeros(pg.n, dtype=np.float64)
        out = np.asarray(out)
        for i in range(pg.p):
            s, c = int(pg.v_start[i]), int(pg.v_count[i])
            ranks[s : s + c] = out[i, :c]
        return ranks


def uniform_ranks(pg: PartitionedGraph) -> jax.Array:
    """The cold-start operand: ``1/n`` on real vertices, zero pad rows."""
    n_rows = core.program_rows(pg)
    rows = jnp.arange(n_rows, dtype=jnp.int32)
    return jnp.where(rows < pg.n, jnp.float32(1.0 / pg.n), jnp.float32(0))


def rank_arg(pg: PartitionedGraph, ranks: np.ndarray) -> jax.Array:
    """Lift a cached global rank vector back into the replicated operand
    (the §16 warm-start seed)."""
    n_rows = core.program_rows(pg)
    buf = np.zeros(n_rows, dtype=np.float32)
    buf[: pg.n] = np.asarray(ranks, dtype=np.float32)[: pg.n]
    return jnp.asarray(buf)


def repair_rank_rows(rows, *, pg: PartitionedGraph, fn, arrays):
    """§16 batch repairer: warm-start re-push of cached rank vectors.

    ``fn`` is the compiled program (same one the cold path runs — warm
    start is purely a different operand), ``arrays`` the engine's placed
    pytree (already refreshed for the mutated partition).  Returns
    ``[(new_row, touched, iters), ...]`` in ``migrate_cache``'s outcome
    contract: ``touched`` counts vertices whose rank moved, ``iters`` the
    re-push rounds (the recompute-vs-repair §16 accounting).
    """
    program = PageRankProgram()
    outcomes = []
    for row in rows:
        out = fn(arrays, rank_arg(pg, row))
        new = program.assemble(pg, np.asarray(out[0]))
        iters = int(np.max(out[1]))
        touched = int(np.sum(~np.isclose(new, row, rtol=1e-6, atol=1e-12)))
        outcomes.append((new if touched else row, touched, iters))
    return outcomes


def pagerank_reference(
    g: Graph, *, damping: float = 0.85, tol: float = 1e-5,
    max_iters: int = 200, init: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Host power iteration (float64) — the PageRank oracle.  Mirrors the
    device semantics exactly: per-edge ``rank[u]/deg_out[u]`` pushes,
    dangling mass redistributed uniformly, total-L1-residual stopping —
    so device float32 results match to float tolerance (documented in
    DESIGN.md §19), not bit-exactly."""
    n = g.n
    offs, dst = g.row_offsets, g.dst
    deg = np.diff(offs).astype(np.float64)
    rank = (np.full(n, 1.0 / n) if init is None
            else np.asarray(init, dtype=np.float64).copy())
    src = np.repeat(np.arange(n), np.diff(offs))
    inv_deg = 1.0 / np.maximum(deg, 1.0)
    for _ in range(max_iters):
        contrib = np.zeros(n)
        np.add.at(contrib, dst, rank[src] * inv_deg[src])
        dangle = rank[deg == 0].sum()
        new = (1.0 - damping) / n + damping * (contrib + dangle / n)
        resid = np.abs(new - rank).sum()
        rank = new
        if resid <= tol:
            break
    return rank
