"""Vertex programs on the butterfly exchange (DESIGN.md §19).

One gather-apply-scatter core (:mod:`repro.programs.core`) serving four
graph-analytics programs, each a ~100-line :class:`VertexProgram` instance
compiled onto the SAME ``jit(shard_map(lax.while_loop))`` skeleton and
density-adaptive butterfly sync as every §13/§14 traversal:

* ``pagerank`` — power iteration; ADD_F32 **delta** sparse mode (the first
  non-idempotent monoid on the sparse path);
* ``cc``       — min-label-propagation connected components; MIN_U32
  remerge mode, bit-exact vs union-find;
* ``tri``      — triangle counting; one OR exchange replicates neighbor
  bitmaps, wedge checks finish locally;
* ``kcore``    — iterative peeling via degree-threshold OR scatter waves.
"""

from __future__ import annotations

from repro.programs.cc import ConnectedComponentsProgram, cc_reference
from repro.programs.core import (
    SYNCS,
    ProgramConfig,
    ProgramContext,
    VertexProgram,
    build_program_fn,
    program_msg_words,
    program_rows,
    run_program,
)
from repro.programs.kcore import KCoreProgram, kcore_reference
from repro.programs.pagerank import (
    PageRankProgram,
    pagerank_reference,
    rank_arg,
    repair_rank_rows,
    uniform_ranks,
)
from repro.programs.triangles import (
    TriangleCountProgram,
    total_triangles,
    triangles_reference,
)

#: The engine/service algo registry: name -> shared program instance
#: (programs are stateless — all run state lives in the loop carry).
PROGRAMS = {
    p.name: p
    for p in (
        PageRankProgram(),
        ConnectedComponentsProgram(),
        TriangleCountProgram(),
        KCoreProgram(),
    )
}

PROGRAM_ALGOS = tuple(PROGRAMS)


def by_name(name: str) -> VertexProgram:
    try:
        return PROGRAMS[name]
    except KeyError:
        raise ValueError(
            f"unknown vertex program {name!r}; expected one of "
            f"{sorted(PROGRAMS)}"
        ) from None


__all__ = [
    "SYNCS",
    "PROGRAMS",
    "PROGRAM_ALGOS",
    "ProgramConfig",
    "ProgramContext",
    "VertexProgram",
    "build_program_fn",
    "by_name",
    "program_msg_words",
    "program_rows",
    "run_program",
    "PageRankProgram",
    "ConnectedComponentsProgram",
    "TriangleCountProgram",
    "KCoreProgram",
    "pagerank_reference",
    "cc_reference",
    "triangles_reference",
    "kcore_reference",
    "total_triangles",
    "uniform_ranks",
    "rank_arg",
    "repair_rank_rows",
]
