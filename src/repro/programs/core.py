"""One gather-apply-scatter core for vertex programs (DESIGN.md §19).

The §13/§14 traversals share one runtime shape — phase-1 local work over
owned out-edges, phase-2 butterfly merge of a replicated buffer, inside
``jit(shard_map(lax.while_loop))`` — but each driver (BFS, MS-BFS, SSSP,
BC) re-states it by hand.  This module factors the shape into a reusable
**vertex program** contract:

* **gather** — each rank folds its owned edges into a flat message buffer
  under the program's :class:`~repro.core.monoid.Monoid`;
* **sync**   — the buffer is merged across ranks by the §5 butterfly
  (dense full-buffer, sparse changed-word, or density-adaptive dispatch —
  the SAME collectives every traversal uses, unchanged);
* **apply**  — each rank folds the merged buffer into the replicated
  per-vertex state and decides convergence;
* **scatter** — the program's activity predicate (a changed bitmap, a
  residual threshold, a peel wave) gates what the next gather touches.

The idempotence/delta dichotomy (``core.monoid``) is enforced here: an
idempotent program (MIN/OR) ships changed-vs-reference full values
(*remerge*), a non-idempotent one (ADD) ships per-rank delta contributions
against ``ref=None`` — each subcube partial is delivered exactly once, so
the sparse wire is bit-identical to the dense reduce.

Any :class:`VertexProgram` instance compiles through
:func:`build_program_fn` to ONE XLA program per ``(graph, mesh, algo,
config)`` — the same compile-once/run-many contract as the traversal
drivers, and the same ``repro.core.loop`` skeleton, so the §18 flight
recorder rides along for free (``trace=True``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core import frontier as fr
from repro.core import loop
from repro.core import monoid as mono
from repro.core.bfs import graph_array_keys, place_arrays
from repro.graph.partition import PartitionedGraph

SYNCS = ("butterfly", "sparse", "adaptive", "all_to_all", "xla")

#: ``lax``-builtin all-reduce per monoid name (the ``sync="xla"`` baseline).
_XLA_REDUCERS = {
    "or": lambda x, a: collectives.xla_allreduce(x, (a,), op="or"),
    "min": lax.pmin,
    "max": lax.pmax,
    "add": lax.psum,
    "add_u32": lax.psum,
}


@dataclasses.dataclass(frozen=True)
class ProgramConfig:
    """Vertex-program knobs, mirroring :class:`repro.traversal.sssp.SSSPConfig`
    (the sync family and its sparse/adaptive knobs are shared semantics);
    ``damping``/``tol`` are read by convergence-style programs (PageRank)."""

    axes: Tuple[str, ...] = ("data",)
    fanout: int = 2
    # butterfly | sparse | adaptive | all_to_all | xla
    sync: str = "butterfly"
    max_iters: Optional[int] = None
    # --- sparse/adaptive sync knobs (shared semantics with SSSPConfig) ----
    sparse_capacity: int = 0  # 0 -> auto-size to n_words // 64 (>= 64)
    density_threshold: float = 0.02
    # --- convergence knobs (PageRank; ignored by exact programs) ----------
    damping: float = 0.85
    tol: float = 1e-5  # total L1 residual threshold

    def __post_init__(self):
        if self.sync not in SYNCS:
            raise ValueError(
                f"unknown program sync {self.sync!r}; expected one of {SYNCS}"
            )
        if not 0.0 < self.damping < 1.0:
            raise ValueError(f"damping must be in (0, 1), got {self.damping}")
        if self.tol <= 0:
            raise ValueError(f"tol must be > 0, got {self.tol}")

    def resolved_capacity(self, n_words: int) -> int:
        cap = self.sparse_capacity or max(64, n_words // 64)
        return min(cap, n_words)


def program_msg_words(pg: PartitionedGraph, program: "VertexProgram") -> int:
    """Host-side :meth:`VertexProgram.msg_words`: programs size their
    exchanged buffer off STATIC context fields only (``n_rows``/``nw``), so
    a stub context suffices — trace buffers and benchmark wire-byte
    accounting need the figure outside ``shard_map``."""
    n_rows = program_rows(pg)
    ctx = ProgramContext(
        cfg=ProgramConfig(), n=pg.n, n_rows=n_rows,
        nw=n_rows // fr.WORD_BITS, vmax=pg.vmax, arrays={},
        v_start=None, v_count=None, vown_ids=None, owned_mask=None,
    )
    return program.msg_words(ctx)


def program_rows(pg: PartitionedGraph, *, lane_pad: int = 128) -> int:
    """Length of a per-vertex replicated buffer: the whole graph plus one
    device window of slack (every device dynamic-slices its owned
    ``[v_start, v_start + vmax)`` range without clamping), lane-padded —
    identical to ``sssp.dist_rows`` / ``msbfs.wave_rows`` sizing."""
    rows = pg.n + pg.vmax
    return (rows + lane_pad - 1) // lane_pad * lane_pad


@dataclasses.dataclass
class ProgramContext:
    """Everything a program's traced callbacks may read.  Static Python
    ints (``n``, ``n_rows``, ``nw``, ``vmax``) are compile-time; the rest
    are traced per-device values inside ``shard_map``."""

    cfg: ProgramConfig
    n: int  # graph vertices (incl. CSR padding)
    n_rows: int  # replicated per-vertex buffer length (program_rows)
    nw: int  # words of an n_rows-bit bitmap
    vmax: int  # owned-window width
    arrays: dict  # per-device placed graph arrays (leading [P] stripped)
    v_start: jax.Array
    v_count: jax.Array
    vown_ids: jax.Array  # int32[vmax] local owned offsets
    owned_mask: jax.Array  # bool[vmax]

    @property
    def edge_mask(self) -> jax.Array:
        """bool[emax]: real owned out-edges (padding slots masked)."""
        src = self.arrays["edge_src"]
        e_ids = jnp.arange(src.shape[0], dtype=jnp.int32)
        return e_ids < self.arrays["edge_count"]

    def owned_slice(self, buf: jax.Array) -> jax.Array:
        """This rank's ``[v_start, v_start + vmax)`` window of a replicated
        per-vertex buffer."""
        return lax.dynamic_slice(buf, (self.v_start,), (self.vmax,))


class VertexProgram:
    """The gather-apply-scatter contract (DESIGN.md §19).

    Subclasses provide a monoid plus five traced callbacks; everything else
    (sync dispatch, convergence loop, trace rows, sharding) is shared.
    All callbacks run INSIDE ``shard_map`` on per-device values.

    * ``name``       — the engine/service algo key;
    * ``monoid``     — the exchange monoid; its :attr:`sparse_mode`
      (remerge vs delta) constrains what ``gather`` may return as ``ref``;
    * ``msg_words(ctx)`` — static length of the exchanged flat buffer;
    * ``init(ctx, arg)`` — initial state tuple from the replicated operand;
    * ``gather(ctx, state, it)`` — ``(msg, ref, work)``: the rank's
      message buffer, the sparse reference (``None`` = delta mode — REQUIRED
      for non-idempotent monoids), and this round's work units (float32);
    * ``apply(ctx, state, merged, it)`` — next state from the merged buffer;
    * ``active(ctx, state, it)`` — keep iterating? (ANDed with
      ``it < max_iters``); must be replicated-consistent;
    * ``outputs(ctx, state)`` — tuple of per-device owned result arrays;
    * ``metrics(ctx, state, merged)`` — ``(pop, direction)`` int32 scalars
      for the §18 trace row: POP is the program's PROGRESS measure
      (PageRank: residual mass in ppm; CC: labels changed; k-core:
      vertices peeled), DIR its phase indicator (k-core: current k).

    Host-side companions: ``default_arg(pg)`` (the cold-start operand) and
    ``assemble(pg, out)`` (per-device owned outputs -> global result).
    """

    name: str = "?"
    monoid: mono.Monoid = mono.OR_U32
    n_outputs: int = 1

    # --- traced callbacks (inside shard_map) ------------------------------

    def msg_words(self, ctx: ProgramContext) -> int:
        return ctx.n_rows

    def init(self, ctx: ProgramContext, arg) -> tuple:
        raise NotImplementedError

    def gather(self, ctx: ProgramContext, state: tuple, it):
        raise NotImplementedError

    def apply(self, ctx: ProgramContext, state: tuple, merged, it) -> tuple:
        raise NotImplementedError

    def active(self, ctx: ProgramContext, state: tuple, it):
        raise NotImplementedError

    def outputs(self, ctx: ProgramContext, state: tuple) -> tuple:
        raise NotImplementedError

    def metrics(self, ctx: ProgramContext, state: tuple, merged):
        return jnp.int32(0), jnp.int32(0)

    # --- host-side companions ---------------------------------------------

    def default_max_iters(self, pg: PartitionedGraph) -> int:
        return 1 << 30

    def default_arg(self, pg: PartitionedGraph):
        return jnp.int32(0)

    def assemble(self, pg: PartitionedGraph, out) -> np.ndarray:
        raise NotImplementedError


def _sync_program(msg, ref, monoid: mono.Monoid, cfg: ProgramConfig,
                  capacity: int):
    """Phase-2 merge of the program's message buffer — the §14 sync
    dispatch generalized over the monoid.  ``ref=None`` selects delta mode
    on the sparse paths (enforced against ``monoid.sparse_mode``)."""
    if cfg.sync == "butterfly":
        return collectives.butterfly_reduce(
            msg, cfg.axes, monoid, fanout=cfg.fanout
        )
    if cfg.sync == "sparse":
        return collectives.butterfly_reduce_sparse(
            msg, cfg.axes, monoid, fanout=cfg.fanout, capacity=capacity,
            ref=ref,
        )
    if cfg.sync == "adaptive":
        return collectives.butterfly_reduce_adaptive(
            msg, cfg.axes, monoid, fanout=cfg.fanout, capacity=capacity,
            density_threshold=cfg.density_threshold, ref=ref,
        )
    if cfg.sync == "all_to_all":
        return collectives.all_to_all_merge(msg, cfg.axes, op=monoid.combine)
    if cfg.sync == "xla":
        reducer = _XLA_REDUCERS[monoid.name]
        out = msg
        for a in cfg.axes:
            out = reducer(out, a)
        return out
    raise ValueError(f"unknown sync {cfg.sync!r}")


def build_program_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, program: VertexProgram,
    cfg: ProgramConfig = ProgramConfig(), *,
    trace: bool = False, trace_levels=None,
):
    """Compile ``program`` to the shared traversal skeleton.

    Returns ``run(arrays, arg)`` where ``arrays`` is the SAME placed graph
    pytree every traversal driver consumes and ``arg`` the program's
    replicated operand (PageRank: the warm-start rank vector; CC: initial
    labels; others: an ignored scalar).  Output:
    ``(*outputs[P, ...], iters int32[P], work float32[P])`` — ``work`` is
    the global edge-examination count (honest-TEPS numerator).

    ``trace=True`` appends the §18 flight-recorder buffer
    ``int32[P, trace_levels, TRACE_COLS]`` with the POP/DIR columns
    reinterpreted per program (see :meth:`VertexProgram.metrics`);
    ``trace=False`` stages the exact uninstrumented program.
    """
    n_rows = program_rows(pg)
    nw = n_rows // fr.WORD_BITS
    vmax = pg.vmax
    max_iters = (cfg.max_iters if cfg.max_iters is not None
                 else program.default_max_iters(pg))
    spec = P(cfg.axes if len(cfg.axes) > 1 else cfg.axes[0])
    if trace:
        from repro.core import flightrec

        t_levels = flightrec.resolve_trace_levels(trace_levels, max_iters)

    def body(arrays, arg):
        arrays = jax.tree.map(lambda a: a[0], arrays)
        vown_ids = jnp.arange(vmax, dtype=jnp.int32)
        ctx = ProgramContext(
            cfg=cfg, n=pg.n, n_rows=n_rows, nw=nw, vmax=vmax,
            arrays=arrays, v_start=arrays["v_start"],
            v_count=arrays["v_count"], vown_ids=vown_ids,
            owned_mask=vown_ids < arrays["v_count"],
        )
        capacity = cfg.resolved_capacity(program.msg_words(ctx))
        state0 = tuple(program.init(ctx, arg))
        k = len(state0)

        def cond(carry):
            return program.active(ctx, carry[:k], carry[k]) & (
                carry[k] < max_iters
            )

        def step(carry):
            state, it, work = carry[:k], carry[k], carry[k + 1]
            msg, ref, w = program.gather(ctx, state, it)
            if trace:
                ref_arr = (program.monoid.full(msg.shape, msg.dtype)
                           if ref is None else ref)
                t_words, t_branch, t_shipped = flightrec.monoid_sync_stats(
                    msg, ref_arr, cfg, capacity
                )
            merged = _sync_program(msg, ref, program.monoid, cfg, capacity)
            state = tuple(program.apply(ctx, state, merged, it))
            out = state + (it + 1, work + w.astype(jnp.float32))
            if not trace:
                return out, None
            pop, direction = program.metrics(ctx, state, merged)
            row = flightrec.trace_row(
                it, t_words, pop, direction, t_branch, t_shipped,
                fr.changed_count(merged.reshape(-1), ref_arr.reshape(-1)),
            )
            return out, (it, row)

        init = state0 + (jnp.int32(0), jnp.float32(0))
        carry = loop.traced_while(
            cond, step, init, trace=trace,
            trace_levels=t_levels if trace else None,
        )
        state, it, work = carry[:k], carry[k], carry[k + 1]
        total_work = lax.psum(work, cfg.axes)
        out = tuple(o[None] for o in program.outputs(ctx, state))
        out = out + (it[None], total_work[None])
        if trace:
            out = out + (carry[k + 2][None],)
        return out

    return loop.jit_shard(
        body, mesh, graph_array_keys(pg), spec,
        n_out=program.n_outputs + 2, trace=trace,
    )


def run_program(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, program: VertexProgram,
    cfg: ProgramConfig = ProgramConfig(), *, arg=None,
) -> Tuple[np.ndarray, int, float]:
    """End-to-end helper: place arrays, compile, run, assemble.

    Returns ``(result, iters, work)`` — the program's global result (see
    each program's ``assemble``), rounds executed, and edges examined.
    """
    arrays = place_arrays(pg, mesh, cfg.axes)
    fn = build_program_fn(pg, mesh, program, cfg)
    if arg is None:
        arg = program.default_arg(pg)
    out = fn(arrays, arg)
    result = program.assemble(pg, np.asarray(out[0]))
    return result, int(np.max(out[program.n_outputs])), float(
        np.asarray(out[program.n_outputs + 1])[0]
    )
