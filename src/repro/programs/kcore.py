"""k-core decomposition as a vertex program (§19): iterative peeling with
degree-threshold scatter waves on the OR butterfly.

Classic peeling lifted to the replicated-bitmap machinery: an ``alive``
bitmap is replicated on every rank; each round every rank recomputes its
owned vertices' alive-degree from its owned out-edges and proposes a PEEL
WAVE — the owned alive vertices with ``deg < k`` — as a bitmap shipped
through the OR exchange (idempotent, ``ref=None``: only nonzero peel words
travel, so late quiet rounds cost almost nothing on the sparse wire).
Peeled vertices get core number ``k - 1``; an empty wave advances the
threshold ``k``.  Terminates when nothing is alive; every round either
peels a vertex or bumps ``k``, so rounds are bounded by ``n + max_core``.

Exact: the host oracle runs the same peel schedule in NumPy and matches
integer-for-integer (degrees count alive out-neighbors of the symmetrized
generator graphs, self-loops dropped, parallel edges counted — the same
multiset both sides see).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import frontier as fr
from repro.core import monoid as mono
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph
from repro.programs import core


class KCoreProgram(core.VertexProgram):
    name = "kcore"
    monoid = mono.OR_U32

    def init(self, ctx, arg):
        alive = fr.pack(jnp.arange(ctx.n_rows, dtype=jnp.int32) < ctx.n)
        core_no = jnp.zeros((ctx.vmax,), jnp.int32)
        return (alive, core_no, jnp.int32(1))

    def active(self, ctx, state, it):
        return fr.popcount(state[0]) > 0

    def msg_words(self, ctx) -> int:
        return ctx.nw  # the peel wave is a packed bitmap, not f32/u32 rows

    def gather(self, ctx, state, it):
        alive, _, k = state
        a = ctx.arrays
        src, dst = a["edge_src"], a["edge_dst"]
        valid = ctx.edge_mask & (src != dst)
        # owned alive-degree from owned out-edges (symmetrized graphs:
        # out-degree == degree)
        alive_dst = fr.get_bits(alive, dst) & valid
        lidx = jnp.where(valid, src - ctx.v_start, 0)
        deg = jnp.zeros((ctx.vmax,), jnp.int32).at[lidx].add(
            alive_dst.astype(jnp.int32)
        )
        alive_own = (
            fr.get_bits(alive, ctx.v_start + ctx.vown_ids) & ctx.owned_mask
        )
        peel = alive_own & (deg < k)
        msg = fr.scatter_or(ctx.nw, ctx.v_start + ctx.vown_ids, peel)
        return msg, None, valid.sum(dtype=jnp.float32)

    def apply(self, ctx, state, merged, it):
        alive, core_no, k = state
        peeled_own = fr.get_bits(merged, ctx.v_start + ctx.vown_ids)
        core_no = jnp.where(peeled_own, k - 1, core_no)
        alive = alive & ~merged
        # empty wave: nothing peelable below k — raise the threshold
        k = jnp.where(fr.popcount(merged) > 0, k, k + 1)
        return (alive, core_no, k)

    def outputs(self, ctx, state):
        return (state[1],)

    def metrics(self, ctx, state, merged):
        # POP: vertices peeled this round; DIR: the current threshold k
        # (the phase indicator of the §18 convergence columns)
        return fr.popcount(merged), state[2]

    def default_max_iters(self, pg: PartitionedGraph) -> int:
        return 2 * pg.n + 64  # every round peels or bumps k (<= max deg + 1)

    def assemble(self, pg: PartitionedGraph, out) -> np.ndarray:
        cores = np.zeros(pg.n, dtype=np.int64)
        out = np.asarray(out)
        for i in range(pg.p):
            s, c = int(pg.v_start[i]), int(pg.v_count[i])
            cores[s : s + c] = out[i, :c]
        return cores


def kcore_reference(g: Graph) -> np.ndarray:
    """Host peeling oracle: ``int64[n]`` core numbers via the same
    schedule the device runs (threshold sweep, alive-out-degree, self-loops
    dropped) — exact integer agreement."""
    n = g.n
    src = np.repeat(np.arange(n), np.diff(g.row_offsets))
    dst = g.dst.astype(np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    alive = np.ones(n, dtype=bool)
    cores = np.zeros(n, dtype=np.int64)
    k = 1
    while alive.any():
        deg = np.zeros(n, dtype=np.int64)
        np.add.at(deg, src, alive[dst].astype(np.int64))
        peel = alive & (deg < k)
        if peel.any():
            cores[peel] = k - 1
            alive &= ~peel
        else:
            k += 1
    return cores
