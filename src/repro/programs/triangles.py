"""Triangle counting as a vertex program (§19): one OR-exchange round
builds the replicated neighbor bitmaps, then owned-edge wedge checks
finish locally.

* **gather (round 0)** — each rank scatter-ORs its owned out-edges into a
  flat row-major adjacency bitmap (row ``u`` = ``n_rows`` bits, bit ``v``
  set iff edge ``(u, v)``; self-loops dropped).  The butterfly OR merge
  replicates the FULL adjacency — the one collective of the whole count.
* **apply** — for every owned edge ``(u, v)``, the wedge count
  ``|N(u) & N(v)|`` is a lane-word AND + popcount against the merged
  bitmaps; accumulated at ``u``, every triangle ``{a,b,c}`` lands exactly
  twice on each corner, so ``tri(v) = acc(v) / 2`` and the global count is
  ``sum(acc) / 6`` — all integer-exact against the host oracle.

Edges are partitioned by source, so each vertex's wedge accumulator is
complete on its owner: the count phase needs NO second exchange.  The
bitmap is ``n_rows^2`` bits replicated per rank — quadratic by design
(this is the dense-neighborhood regime the paper's §13 bit-lane layout
targets); :meth:`TriangleCountProgram.msg_words` rejects graphs whose flat
bit index would overflow int32.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

from repro.core import frontier as fr
from repro.core import monoid as mono
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph
from repro.programs import core

#: Largest replicated-bitmap side whose flat bit index fits int32.
MAX_ROWS = 46340  # floor(sqrt(2^31))


class TriangleCountProgram(core.VertexProgram):
    name = "tri"
    monoid = mono.OR_U32

    def msg_words(self, ctx) -> int:
        if ctx.n_rows > MAX_ROWS:
            raise ValueError(
                f"triangle program needs n_rows^2 bits addressable by "
                f"int32: n_rows={ctx.n_rows} > {MAX_ROWS}"
            )
        return ctx.n_rows * (ctx.n_rows // fr.WORD_BITS)

    def init(self, ctx, arg):
        return (jnp.zeros((ctx.vmax,), jnp.int32),)

    def active(self, ctx, state, it):
        return it < 1  # one exchange round; counting is local

    def gather(self, ctx, state, it):
        a = ctx.arrays
        src, dst = a["edge_src"], a["edge_dst"]
        valid = ctx.edge_mask & (src != dst)
        # flat bit index: row-major (u, v) -> u * n_rows + v
        bits = src * jnp.int32(ctx.n_rows) + dst
        adj = fr.scatter_or(self.msg_words(ctx), bits, valid)
        return adj, None, valid.sum(dtype=jnp.float32)

    def apply(self, ctx, state, merged, it):
        a = ctx.arrays
        src, dst = a["edge_src"], a["edge_dst"]
        valid = ctx.edge_mask & (src != dst)
        adjm = merged.reshape(ctx.n_rows, ctx.n_rows // fr.WORD_BITS)
        common = lax.population_count(adjm[src] & adjm[dst]).sum(
            axis=1, dtype=jnp.int32
        )
        lidx = jnp.where(valid, src - ctx.v_start, 0)
        acc = jnp.zeros((ctx.vmax,), jnp.int32).at[lidx].add(
            jnp.where(valid, common, 0)
        )
        return (state[0] + acc,)

    def outputs(self, ctx, state):
        return (state[0],)

    def metrics(self, ctx, state, merged):
        # POP: wedge hits accumulated this round, globally (replicated so
        # every rank's trace row agrees)
        wedges = lax.psum(state[0].sum(dtype=jnp.int32), ctx.cfg.axes)
        return wedges, jnp.int32(0)

    def default_max_iters(self, pg: PartitionedGraph) -> int:
        return 1

    def assemble(self, pg: PartitionedGraph, out) -> np.ndarray:
        """Per-vertex triangle counts ``int64[n]`` (each corner's incident
        triangles); the wedge accumulator lands twice per triangle corner.
        """
        acc = np.zeros(pg.n, dtype=np.int64)
        out = np.asarray(out)
        for i in range(pg.p):
            s, c = int(pg.v_start[i]), int(pg.v_count[i])
            acc[s : s + c] = out[i, :c]
        return acc // 2


def total_triangles(per_vertex: np.ndarray) -> int:
    """Global triangle count from :meth:`assemble`'s per-vertex counts
    (every triangle has three corners)."""
    return int(per_vertex.sum() // 3)


def triangles_reference(g: Graph) -> np.ndarray:
    """Host oracle: per-vertex triangle counts ``int64[n]`` via the same
    wedge semantics the device uses — neighbor BITSETS (duplicate edges
    collapse, self-loops dropped) intersected along every directed edge,
    halved per corner.  On the symmetrized generator graphs this is the
    standard undirected triangle count."""
    n = g.n
    src = np.repeat(np.arange(n), np.diff(g.row_offsets))
    nbr = [set() for _ in range(n)]
    for u, v in zip(src.tolist(), g.dst.tolist()):
        if u != v:
            nbr[u].add(v)
    acc = np.zeros(n, dtype=np.int64)
    for u, v in zip(src.tolist(), g.dst.tolist()):
        if u != v:
            acc[u] += len(nbr[u] & nbr[v])
    return acc // 2
