"""Label-propagation connected components as a vertex program (§19).

Min-label propagation over the ``MIN_U32`` butterfly: every vertex starts
as its own label (its id), each round CHANGED vertices push their label to
both endpoints of every incident owned edge (both directions, so weak
connectivity holds on directed inputs), and the sparse exchange ships only
changed-vs-previous label words (**remerge** mode — MIN is idempotent, so
re-delivering a full value is harmless).  Converged labels are the minimum
vertex id of each weakly-connected component — exact, so the host oracle
(union-find) matches bit-for-bit.

The changed-vertex bitmap IS the scatter predicate: a quiescent region
costs neither phase-1 proposals nor sparse wire words, exactly like the
SSSP changed-distance frontier it generalizes.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import frontier as fr
from repro.core import monoid as mono
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph
from repro.programs import core

#: Pad-row label == the MIN identity (never a real vertex id).
NO_LABEL = 0xFFFFFFFF


class ConnectedComponentsProgram(core.VertexProgram):
    name = "cc"
    monoid = mono.MIN_U32

    def init(self, ctx, arg):
        # arg: replicated uint32[n_rows] initial labels (identity iota cold
        # start); every real vertex starts changed — round 1 pushes ids
        changed = fr.pack(
            jnp.arange(ctx.n_rows, dtype=jnp.int32) < ctx.n
        )
        return (arg, changed)

    def active(self, ctx, state, it):
        return fr.popcount(state[1]) > 0

    def gather(self, ctx, state, it):
        labels, changed = state
        a = ctx.arrays
        src, dst = a["edge_src"], a["edge_dst"]
        emask = ctx.edge_mask
        inf = jnp.uint32(NO_LABEL)
        # both directions from the owned edge list (labels are replicated,
        # so the owner of u can propose v -> u without owning v)
        src_on = fr.get_bits(changed, src) & emask
        dst_on = fr.get_bits(changed, dst) & emask
        fwd = jnp.where(src_on, labels[src], inf)
        bwd = jnp.where(dst_on, labels[dst], inf)
        # msg starts AT the reference and only improves: the remerge
        # monotonicity contract (msg == combine(msg, ref)) by construction
        msg = labels.at[dst].min(fwd).at[src].min(bwd)
        work = (src_on.sum(dtype=jnp.float32) + dst_on.sum(dtype=jnp.float32))
        return msg, labels, work

    def apply(self, ctx, state, merged, it):
        labels = state[0]
        changed = fr.pack(merged < labels)
        return (merged, changed)

    def outputs(self, ctx, state):
        return (ctx.owned_slice(state[0]),)

    def metrics(self, ctx, state, merged):
        # POP: labels changed this round (the convergence trace column)
        return fr.popcount(state[1]), jnp.int32(0)

    def default_max_iters(self, pg: PartitionedGraph) -> int:
        return pg.n + 1  # min-label propagation worst case (a path)

    def default_arg(self, pg: PartitionedGraph):
        return identity_labels(pg)

    def assemble(self, pg: PartitionedGraph, out) -> np.ndarray:
        labels = np.full(pg.n, NO_LABEL, dtype=np.int64)
        out = np.asarray(out)
        for i in range(pg.p):
            s, c = int(pg.v_start[i]), int(pg.v_count[i])
            labels[s : s + c] = out[i, :c]
        return labels


def identity_labels(pg: PartitionedGraph):
    """Cold-start labels: each real vertex its own id, pad rows the MIN
    identity (they never propose — no edges touch them)."""
    n_rows = core.program_rows(pg)
    rows = jnp.arange(n_rows, dtype=jnp.uint32)
    return jnp.where(rows < pg.n, rows, jnp.uint32(NO_LABEL))


def cc_reference(g: Graph) -> np.ndarray:
    """Host union-find oracle: ``int64[n]``, each vertex labelled with the
    minimum vertex id of its weakly-connected component — the exact fixed
    point of min-label propagation."""
    parent = np.arange(g.n, dtype=np.int64)

    def find(v):
        root = v
        while parent[root] != root:
            root = parent[root]
        while parent[v] != root:
            parent[v], v = root, parent[v]
        return root

    src = np.repeat(np.arange(g.n), np.diff(g.row_offsets))
    for u, v in zip(src.tolist(), g.dst.tolist()):
        ru, rv = find(u), find(v)
        if ru != rv:
            # union by min root keeps every root the component minimum
            if ru < rv:
                parent[rv] = ru
            else:
                parent[ru] = rv
    return np.array([find(v) for v in range(g.n)], dtype=np.int64)
