"""Jamba-v0.1-52B [arXiv:2403.19887; hf]: hybrid Mamba+attention 1:7
interleave (one attention layer per 8, offset 4), MoE 16e top-2 on every
other layer.  SSM decode state is O(1) => long_500k runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    n_experts=16,
    experts_per_token=2,
    d_expert=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_period=8,
    attn_offset=4,
    fsdp=True,
    supports_long_context=True,
    train_microbatches=16,
)
