"""Architecture + shape config system.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``src/repro/configs/<id>.py``) with the exact public-literature numbers from
the brief.  ``reduced()`` derives the small same-family config used by the CPU
smoke tests; the full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm_np (non-parametric, olmo)
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    # --- sliding-window attention (gemma3): repeating pattern of layer kinds,
    # e.g. 5 local : 1 global.  window == 0 means all layers are global.
    local_window: int = 0
    locals_per_global: int = 0  # e.g. 5 -> pattern LLLLLG repeating

    # --- MoE
    n_experts: int = 0
    experts_per_token: int = 0
    d_expert: int = 0  # expert FF width (d_ff used for dense blocks)
    n_shared_experts: int = 0
    first_dense_layers: int = 0  # leading dense-MLP layers (kimi: 1)
    moe_every: int = 1  # a layer is MoE iff layer_idx % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (jamba): one attention layer per `attn_period` layers at
    # `attn_offset`; remaining layers are mamba blocks.
    attn_period: int = 0
    attn_offset: int = 0

    # --- encoder-decoder (whisper) / modality frontends (stubs)
    encoder_layers: int = 0
    n_frames: int = 0  # whisper: precomputed conv-frontend frame embeddings
    n_patches: int = 0  # vlm: precomputed ViT patch embeddings (prefix tokens)
    patch_dim: int = 0  # raw patch embedding width before projection

    # --- distribution / memory policy
    fsdp: bool = False  # additionally shard params over the data axis (ZeRO-3)
    optimizer: str = "adamw"  # adamw | adafactor (factored states, 1T-scale)
    remat: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # gradient-accumulation microbatches for the train_4k shape: bounds
    # saved-activation memory (remat keeps one layer input per layer per
    # LIVE microbatch).  Runtime-memory knob only; per-step flop totals are
    # microbatch-invariant, so the analysis compile uses microbatches=1.
    train_microbatches: int = 1
    # microbatch-accumulator dtype: float32 default; bfloat16 for the 1T
    # arch where a f32 grad tree alone is 16 GB/chip (4TB/256) — adafactor's
    # per-tensor normalization tolerates bf16 grads (EXPERIMENTS §Dry-run).
    grad_accum_dtype: str = "float32"
    # analysis mode: fully unroll layer scans so XLA cost_analysis counts
    # every layer (it counts loop bodies ONCE; verified — see DESIGN.md §10).
    # Runtime configs keep scans (small HLO, streaming FSDP); the dry-run
    # flips this on.
    scan_unroll: bool = False

    # --- §Perf hillclimb knobs (EXPERIMENTS.md; default off = baseline) ---
    # decode: unrolled layer loop with .at[i] cache updates so the donated
    # cache buffer is reused in place instead of scan double-buffering.
    decode_inplace: bool = False
    # decode: sliding-window layers keep a ring buffer of `local_window`
    # KV entries instead of the full seq_len cache (32x smaller at 32k).
    ring_local_cache: bool = False

    # --- which shapes are runnable (sub-quadratic rule from the brief)
    supports_long_context: bool = False  # long_500k cell
    # -----------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head shard
        cleanly over 16-way TP (Megatron's make-vocab-size-divisible-by).
        Pad rows are masked to -inf in the loss and at sampling."""
        if self.vocab < 2048:
            return self.vocab  # smoke configs: keep exact
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        """hybrid only: which layers are attention (vs mamba)."""
        if self.family == "ssm":
            return False
        if self.attn_period == 0:
            return True
        return (i % self.attn_period) == self.attn_offset

    def is_global_attn_layer(self, i: int) -> bool:
        if self.local_window == 0 or self.locals_per_global == 0:
            return True
        return (i % (self.locals_per_global + 1)) == self.locals_per_global


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The brief's rule: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "long_500k skipped: pure full-attention architecture (O(L^2) "
            "prefill / full-cache decode); see DESIGN.md §Arch-applicability"
        )
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests (one fwd/train step)."""
    period = 1
    if cfg.attn_period:
        period = cfg.attn_period
    if cfg.locals_per_global:
        period = max(period, cfg.locals_per_global + 1)
    period = max(period, cfg.moe_every, 2)
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=max(period, cfg.first_dense_layers + period),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=256,
        vocab=512,
        head_dim=32,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2),
        d_expert=64 if cfg.d_expert else 0,
        # smoke configs are DROPLESS (capacity >= L*k) so prefill+decode is
        # bit-consistent with the full forward; training at scale uses the
        # real capacity_factor (token dropping), tested separately.
        capacity_factor=8.0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_frames=8 if cfg.n_frames else 0,
        n_patches=8 if cfg.n_patches else 0,
        patch_dim=64 if cfg.patch_dim else 0,
        fsdp=False,
        remat=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
    return dataclasses.replace(cfg, **changes)
