"""Kimi-K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table]: 384 experts
top-8 + 1 shared expert, first layer dense (DeepSeek-V3 lineage).
d_ff=2048 is the per-expert width; the dense layer uses 18432.
Optimizer states are factored (adafactor) -- 1T AdamW moments cannot fit a
256-chip v5e pod (see EXPERIMENTS.md dry-run table)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18432,          # dense-block FF width (first layer)
    vocab=163840,
    head_dim=128,
    qk_norm=True,
    n_experts=384,
    experts_per_token=8,
    d_expert=2048,
    n_shared_experts=1,
    first_dense_layers=1,
    fsdp=True,
    optimizer="adafactor",
    train_microbatches=16,
    grad_accum_dtype="bfloat16",
)
