"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; hf]: GQA kv=8, qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    train_microbatches=4,
)
