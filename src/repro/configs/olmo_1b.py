"""OLMo-1B [arXiv:2402.00838; hf]: dense, non-parametric LayerNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    head_dim=128,
    norm="layernorm_np",  # OLMo's non-parametric LN
    tie_embeddings=True,
    train_microbatches=2,
)
