"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf]: 128 experts
top-8, every layer MoE, GQA kv=4, qk-norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,           # == expert width; no dense blocks
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    experts_per_token=8,
    d_expert=1536,
    fsdp=True,
    optimizer="adafactor",
    train_microbatches=16,
)
