"""InternVL2-26B [arXiv:2404.16821; hf]: InternViT-6B vision frontend (STUB:
input_specs() provides precomputed patch embeddings of width 3200, projected
into the LM) + InternLM2-20B text backbone."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    n_patches=256,
    patch_dim=3200,
    fsdp=True,
    train_microbatches=16,
)
