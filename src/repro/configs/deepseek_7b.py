"""DeepSeek-LLM-7B [arXiv:2401.02954; hf]: llama-arch dense MHA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    head_dim=128,
    fsdp=True,  # AdamW moments replicated over data blow 16GB otherwise
    train_microbatches=4,
)
