"""Whisper-medium [arXiv:2212.04356; unverified]: encoder-decoder transformer
backbone.  The conv audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (1500 frames after 2x conv downsampling)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    head_dim=64,
    norm="layernorm",
    n_frames=1500,
    tie_embeddings=True,
    train_microbatches=2,
)
