"""Architecture registry: ``--arch <id>`` -> :class:`ModelConfig`.

Sources are cited per-module; numbers are exactly the brief's assignment.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    ModelConfig,
    ShapeConfig,
    SHAPES,
    reduced,
    shape_supported,
)

# arch id -> module name under repro.configs
_MODULES: Dict[str, str] = {
    "olmo-1b": "olmo_1b",
    "qwen3-1.7b": "qwen3_1p7b",
    "deepseek-7b": "deepseek_7b",
    "gemma3-27b": "gemma3_27b",
    "mamba2-130m": "mamba2_130m",
    "kimi-k2-1t-a32b": "kimi_k2",
    "qwen3-moe-235b-a22b": "qwen3_moe",
    "jamba-v0.1-52b": "jamba_52b",
    "whisper-medium": "whisper_medium",
    "internvl2-26b": "internvl2_26b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
