"""Gemma-3-27B [hf:google/gemma-3 family; unverified]: 5:1 local:global
sliding-window interleave (window 1024), qk-norm, 262k vocab, 128k ctx.

long_500k RUNS: the dominant attention cost is the 1024-token local window;
global layers are 1-in-6 and linear-in-cache at decode."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    local_window=1024,
    locals_per_global=5,
    tie_embeddings=True,
    fsdp=True,
    supports_long_context=True,
    train_microbatches=8,
)
