"""Mamba2-130M [arXiv:2405.21060; unverified]: SSD (state-space duality),
attention-free; O(1)-state decode => long_500k runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    supports_long_context=True,
    train_microbatches=2,
)
