"""Weighted traversals on the monoid-generalized butterfly (DESIGN.md §14).

The butterfly frontier exchange factored over an explicit
:class:`repro.core.monoid.Monoid` carries more than reachability:

* :mod:`repro.traversal.sssp` — single-source shortest paths: level-
  synchronous relaxation with delta-stepping-style bucket frontiers,
  distances synchronized by a butterfly MIN-reduce (dense, sparse
  changed-word, or density-adaptive wire format).
* :mod:`repro.traversal.bc` — Brandes betweenness centrality riding the
  MS-BFS bit-lanes: the forward wave counts shortest paths with a
  butterfly ADD-reduce on ``sigma``; the backward pass replays levels in
  reverse accumulating dependencies with the same exchange.

Both compile to ONE XLA program each — ``jit(shard_map(lax.while_loop))``
— exactly like the BFS driver they generalize.
"""

from repro.traversal.sssp import (  # noqa: F401
    SSSPConfig,
    UNREACHED,
    build_sssp_fn,
    distributed_sssp,
    sssp_reference,
)
from repro.traversal.bc import (  # noqa: F401
    bc_reference,
    betweenness_centrality,
    build_bc_fn,
)
