"""Distributed Brandes betweenness centrality on the MS-BFS bit-lanes.

Brandes (2001) decomposes betweenness into per-source *dependencies*:

  ``BC(v) = sum_s delta_s(v)``,
  ``delta_s(v) = sum_{w: succ} sigma_s(v)/sigma_s(w) * (1 + delta_s(w))``

Riding the §13 lane machinery, B sources run concurrently (DESIGN.md §14):

* **Forward wave** — the lane-packed frontier expands exactly like MS-BFS
  (phase 1 push + phase 2 butterfly OR), while per-lane shortest-path
  counts ``sigma[v, b]`` accumulate: each rank sums ``sigma[u]`` over its
  OWNED in-edges ``(u -> v)`` with ``u`` in the frontier and ``v`` newly
  reached, and the disjoint partial sums merge with a butterfly ADD-reduce
  (the non-idempotent monoid rides the dense exchange).  Per-lane levels
  are captured en route.
* **Backward replay** — levels run in reverse: each rank scores its OWNED
  out-edges ``(u -> w)`` with ``lvl[u] == L-1`` and ``lvl[w] == L`` as
  ``sigma[u]/sigma[w] * (1 + delta[w])``, scatter-adds into ``delta[u]``,
  and the partials merge with the same butterfly ADD-reduce.  No per-level
  frontier history is stored — the level array IS the replay index.

Forward and backward together compile to ONE XLA program:
``jit(shard_map(lax.while_loop))`` twice inside one ``shard_map`` body.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core import frontier as fr
from repro.core import loop
from repro.core.bfs import (
    INF,
    BFSConfig,
    _expand_push,
    _sync_frontier,
    graph_array_keys,
    place_arrays,
)
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph
from repro.analytics.msbfs import lane_words, wave_rows


# ---------------------------------------------------------------------------
# Host oracle (Brandes)
# ---------------------------------------------------------------------------


def bc_reference(g: Graph, sources: Sequence[int]) -> np.ndarray:
    """Host Brandes over the given sources — ground truth for every BC test.

    Unnormalized directed-pair accumulation (each ordered pair ``(s, t)``
    contributes once); on the symmetric graphs the ETL produces this is 2x
    the undirected convention, matching the distributed path exactly.
    Returns ``float64[n]``.
    """
    bc = np.zeros(g.n, dtype=np.float64)
    offs, dst = g.row_offsets, g.dst
    for s in sources:
        s = int(s)
        sigma = np.zeros(g.n)
        sigma[s] = 1.0
        d = np.full(g.n, -1, dtype=np.int64)
        d[s] = 0
        order = [s]
        frontier = [s]
        while frontier:
            nxt = []
            for u in frontier:
                for v in dst[offs[u] : offs[u + 1]]:
                    if d[v] < 0:
                        d[v] = d[u] + 1
                        nxt.append(int(v))
            for u in frontier:
                for v in dst[offs[u] : offs[u + 1]]:
                    if d[v] == d[u] + 1:
                        sigma[v] += sigma[u]
            order.extend(nxt)
            frontier = nxt
        delta = np.zeros(g.n)
        for u in reversed(order):
            for v in dst[offs[u] : offs[u + 1]]:
                if d[v] == d[u] + 1:
                    delta[u] += sigma[u] / sigma[v] * (1.0 + delta[v])
        delta[s] = 0.0
        bc += delta
    return bc


# ---------------------------------------------------------------------------
# Distributed BC
# ---------------------------------------------------------------------------


def _sync_add(buf: jax.Array, cfg: BFSConfig) -> jax.Array:
    """ADD all-reduce of per-rank partial sums.  ADD is not idempotent, so
    the sparse changed-word wire format does not apply — sparse/adaptive
    configs ride the dense butterfly here while their frontier OR-sync
    stays sparse (DESIGN.md §14)."""
    if cfg.sync == "all_to_all":
        return collectives.all_to_all_merge(buf, cfg.axes, op="add")
    if cfg.sync == "xla":
        return lax.psum(buf, cfg.axes)
    if cfg.sync == "rabenseifner":
        return collectives.butterfly_allreduce_rabenseifner(
            buf, cfg.axes, fanout=cfg.fanout
        )
    return collectives.butterfly_allreduce(buf, cfg.axes, fanout=cfg.fanout)


def build_bc_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: BFSConfig,
    n_lanes: int, *, trace: bool = False, trace_levels=None,
):
    """Compile-ready B-lane betweenness centrality.

    Returns ``run(arrays, roots)`` where ``roots`` is a replicated
    ``int32[n_lanes]`` (``-1`` = inactive lane).  Output: per-device owned
    dependency sums ``float32[P, vmax]`` (the BC contribution of this
    wave's sources, root rows excluded per lane), wave depth ``int32[P]``,
    and edges examined ``float32[P]``.

    ``trace=True`` appends the §18 flight-recorder buffer for the FORWARD
    wave's frontier OR sync (the backward replay makes no sparse/direction
    decisions — it re-walks the recorded levels with the dense ADD merge,
    one extra dense sync per level, which ``TraversalTrace.summary()``
    reports as ``extra_dense_syncs``).  ``trace=False`` stages the exact
    uninstrumented program.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if cfg.mode != "top_down":
        raise NotImplementedError(
            "betweenness centrality uses the push traversal; build the "
            "config with mode='top_down'"
        )
    if cfg.use_pallas:
        raise NotImplementedError(
            "use_pallas=True is single-source only; BC uses the XLA path"
        )
    bw = lane_words(n_lanes)
    n_rows = wave_rows(pg)
    vmax = pg.vmax
    max_levels = cfg.max_levels if cfg.max_levels is not None else pg.n
    spec = P(cfg.axes if len(cfg.axes) > 1 else cfg.axes[0])
    if trace:
        from repro.core import flightrec

        t_levels = flightrec.resolve_trace_levels(trace_levels, max_levels)

    def body(arrays, roots):
        arrays = jax.tree.map(lambda a: a[0], arrays)
        v_start = arrays["v_start"]
        v_count = arrays["v_count"]
        vown_ids = jnp.arange(vmax, dtype=jnp.int32)
        owned_mask = vown_ids < v_count

        lane_ids = jnp.arange(n_lanes, dtype=jnp.int32)
        lane_active = roots >= 0
        seed_rows = jnp.where(lane_active, roots, 0).astype(jnp.int32)
        onehot = (
            jnp.arange(bw * fr.WORD_BITS, dtype=jnp.int32)[None, :]
            == lane_ids[:, None]
        ) & lane_active[:, None]
        seen0 = fr.scatter_or_lanes(n_rows, seed_rows, fr.lane_pack(onehot))

        sigma0 = jnp.zeros((n_rows, n_lanes), jnp.float32).at[
            seed_rows, lane_ids
        ].add(lane_active.astype(jnp.float32))
        lvl0 = jnp.full((n_rows, n_lanes), INF, jnp.int32).at[
            seed_rows, lane_ids
        ].min(jnp.where(lane_active, 0, INF))

        isrc, idst = arrays["in_src"], arrays["in_dst"]
        imask = jnp.arange(isrc.shape[0], dtype=jnp.int32) < arrays["in_count"]
        osrc, odst = arrays["edge_src"], arrays["edge_dst"]
        omask = jnp.arange(osrc.shape[0], dtype=jnp.int32) < arrays["edge_count"]

        def lanes_of(buf_rows):
            return fr.lane_unpack(buf_rows)[..., :n_lanes]

        # ---- forward wave: frontier expansion + sigma accumulation ------
        def fcond(state):
            frontier, seen, lvl, sigma, level, scanned = state[:6]
            return (fr.popcount(frontier) > 0) & (level < max_levels)

        def fstep(state):
            frontier, seen, lvl, sigma, level, scanned = state[:6]

            gq = _expand_push(arrays, frontier, n_rows, False, lanes=True)
            if trace:
                t_words, t_branch, t_shipped = flightrec.or_sync_stats(
                    gq.reshape(-1), cfg
                )
            merged = _sync_frontier(gq.reshape(-1), cfg).reshape(n_rows, bw)
            new = merged & ~seen

            # sigma increments over OWNED in-edges u -> v (v newly reached,
            # u in the closing level's frontier); partial sums are disjoint
            # across ranks, so one ADD all-reduce finalizes the level.
            u_front = lanes_of(frontier[isrc])
            v_new = lanes_of(new[idst])
            contrib = jnp.where(
                u_front & v_new & imask[:, None], sigma[isrc], 0.0
            )
            partial = jnp.zeros((n_rows, n_lanes), jnp.float32).at[idst].add(
                contrib
            )
            sigma = sigma + _sync_add(
                partial.reshape(-1), cfg
            ).reshape(n_rows, n_lanes)

            lvl = jnp.where(lanes_of(new), level + 1, lvl)

            # edges examined: out-degree of owned frontier rows, per lane
            owned_front = lanes_of(
                lax.dynamic_slice(frontier, (v_start, 0), (vmax, bw))
            ) & owned_mask[:, None]
            m_f = (arrays["deg_out"][:, None] * owned_front).sum()

            out = (
                new,
                seen | new,
                lvl,
                sigma,
                level + 1,
                scanned + m_f.astype(jnp.float32),
            )
            if not trace:
                return out, None
            row = flightrec.trace_row(
                level, t_words, fr.popcount(new), jnp.int32(0), t_branch,
                t_shipped, jnp.count_nonzero(new).astype(jnp.int32),
            )
            return out, (level, row)

        finit = (seen0, seen0, lvl0, sigma0, jnp.int32(0), jnp.float32(0))
        fstate = loop.traced_while(
            fcond, fstep, finit, trace=trace,
            trace_levels=t_levels if trace else None,
        )
        _, _, lvl, sigma, depth, scanned = fstate[:6]

        # ---- backward replay: dependency accumulation, deepest first ----
        sig_src = sigma[osrc]
        sig_dst = jnp.maximum(sigma[odst], 1.0)  # reached => sigma >= 1
        lvl_src = lvl[osrc]
        lvl_dst = lvl[odst]

        def bcond(state):
            delta, level = state
            return level >= 1

        def bstep(state):
            delta, level = state
            on_dag = (
                (lvl_src == level - 1) & (lvl_dst == level) & omask[:, None]
            )
            c = jnp.where(
                on_dag, sig_src / sig_dst * (1.0 + delta[odst]), 0.0
            )
            partial = jnp.zeros((n_rows, n_lanes), jnp.float32).at[osrc].add(c)
            inc = _sync_add(partial.reshape(-1), cfg).reshape(n_rows, n_lanes)
            return (delta + inc, level - 1), None

        delta0 = jnp.zeros((n_rows, n_lanes), jnp.float32)
        delta, _ = loop.traced_while(bcond, bstep, (delta0, depth))

        # a source never scores its own lane (Brandes excludes s)
        delta = delta.at[seed_rows, lane_ids].set(0.0)
        bc_owned = lax.dynamic_slice(delta, (v_start, 0), (vmax, n_lanes)).sum(
            axis=1
        )
        total_scanned = lax.psum(scanned, cfg.axes)
        out = (bc_owned[None], depth[None], total_scanned[None])
        if trace:
            out = out + (fstate[6][None],)
        return out

    return loop.jit_shard(body, mesh, graph_array_keys(pg), spec, trace=trace)


def assemble_bc(pg: PartitionedGraph, bc_owned: np.ndarray) -> np.ndarray:
    """``bc_owned [P, vmax]`` -> global ``float64[n]``."""
    bc_owned = np.asarray(bc_owned)
    out = np.zeros(pg.n, dtype=np.float64)
    for i in range(pg.p):
        s, c = int(pg.v_start[i]), int(pg.v_count[i])
        out[s : s + c] = bc_owned[i, :c]
    return out


def betweenness_centrality(
    pg: PartitionedGraph,
    mesh: jax.sharding.Mesh,
    sources: Sequence[int],
    cfg: BFSConfig = BFSConfig(),
) -> Tuple[np.ndarray, int, float]:
    """End-to-end helper: one wave over ``sources`` (one lane per source).

    Returns ``(bc float64[n], depth, scanned)``; ``bc`` matches
    :func:`bc_reference` over the same sources.  ``-1`` marks an inactive
    lane; any other out-of-range source raises.
    """
    sources = np.asarray(sources, dtype=np.int32)
    if sources.ndim != 1 or sources.size < 1:
        raise ValueError("sources must be a non-empty 1-D sequence")
    if np.any((sources < -1) | (sources >= pg.n)):
        raise ValueError(
            f"source out of range (n={pg.n}, -1=inactive): {sources}"
        )
    arrays = place_arrays(pg, mesh, cfg.axes)
    fn = build_bc_fn(pg, mesh, cfg, int(sources.size))
    bc_owned, depth, scanned = fn(arrays, jnp.asarray(sources))
    return (
        assemble_bc(pg, bc_owned),
        int(np.max(depth)),
        float(np.asarray(scanned)[0]),
    )
