"""Distributed single-source shortest paths on the butterfly MIN-monoid.

The BFS recipe (paper Alg. 2) generalized from reachability to weighted
distances (DESIGN.md §14):

* **Phase 1 — relaxation** (per device): every owned out-edge ``(u, v, w)``
  whose source is in the active frontier proposes ``dist[u] + w`` for
  ``v``; proposals land with a scatter-MIN (the idempotent analogue of the
  BFS scatter-OR).
* **Phase 2 — butterfly distance synchronization**: the per-rank tentative
  distance buffer ``uint32[n_rows]`` is merged across ranks with
  ``butterfly_reduce(MIN_U32)`` — dense full-buffer, sparse changed-word
  (compact ``(vertex, dist)`` pairs vs the post-last-sync reference, padded
  with the ``0xFFFFFFFF`` identity), or density-adaptive dispatch between
  the two.  The unreached sentinel IS the monoid identity, so sparse
  padding is free exactly like the OR path's zero words.

The frontier of CHANGED vertices is a packed bitmap reusing the §3
machinery; with ``delta > 0`` only changed vertices with
``dist < (bucket + 1) * delta`` are expanded per iteration
(delta-stepping-style bucket frontiers — improved vertices re-enter the
frontier, so convergence is Bellman-Ford's).  The whole traversal is ONE
XLA program: ``jit(shard_map(lax.while_loop))``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core import frontier as fr
from repro.core import loop
from repro.core import monoid as mono
from repro.core.bfs import graph_array_keys, place_arrays
from repro.graph.csr import Graph
from repro.graph.partition import PartitionedGraph

#: Unreached sentinel == the MIN monoid identity (uint32 max).
UNREACHED = 0xFFFFFFFF

SYNCS = ("butterfly", "sparse", "adaptive", "all_to_all", "xla")


# ---------------------------------------------------------------------------
# Host oracle (Dijkstra)
# ---------------------------------------------------------------------------


def sssp_reference(g: Graph, root: int) -> np.ndarray:
    """Host Dijkstra — ground truth for every SSSP test.  Returns
    ``int64[n]`` distances with :data:`UNREACHED` for unreachable."""
    if g.weights is None:
        raise ValueError("sssp_reference requires a weighted graph")
    d = np.full(g.n, UNREACHED, dtype=np.int64)
    d[root] = 0
    heap = [(0, int(root))]
    offs, dst, w = g.row_offsets, g.dst, g.weights
    while heap:
        du, u = heapq.heappop(heap)
        if du > d[u]:
            continue
        for v, wv in zip(
            dst[offs[u] : offs[u + 1]], w[offs[u] : offs[u + 1]]
        ):
            nd = du + int(wv)
            if nd < d[v]:
                d[v] = nd
                heapq.heappush(heap, (nd, int(v)))
    return d


# ---------------------------------------------------------------------------
# Distributed SSSP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SSSPConfig:
    """Algorithm knobs, mirroring :class:`repro.core.bfs.BFSConfig`."""

    axes: Tuple[str, ...] = ("data",)
    fanout: int = 2
    # butterfly | sparse | adaptive | all_to_all | xla
    sync: str = "butterfly"
    # bucket width of the delta-stepping-style frontier; 0 = plain
    # level-synchronous relaxation (every changed vertex expands each round)
    delta: int = 0
    max_iters: Optional[int] = None
    # --- sparse/adaptive sync knobs (shared semantics with BFSConfig) -----
    sparse_capacity: int = 0  # 0 -> auto-size to n_rows // 64 (>= 64)
    density_threshold: float = 0.02

    def __post_init__(self):
        if self.sync not in SYNCS:
            raise ValueError(
                f"unknown distance sync {self.sync!r}; expected one of {SYNCS}"
            )
        if self.delta < 0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")

    def resolved_capacity(self, n_rows: int) -> int:
        cap = self.sparse_capacity or max(64, n_rows // 64)
        return min(cap, n_rows)


def dist_rows(pg: PartitionedGraph, *, lane_pad: int = 128) -> int:
    """Length of the exchanged distance buffer: the whole graph plus one
    device window of slack (every device dynamic-slices its owned
    ``[v_start, v_start + vmax)`` range without clamping), lane-padded —
    the per-vertex analogue of the §3 bitmap sizing."""
    rows = pg.n + pg.vmax
    return (rows + lane_pad - 1) // lane_pad * lane_pad


def _sync_dist(
    new: jax.Array, prev: jax.Array, cfg: SSSPConfig, capacity: int
) -> jax.Array:
    """Phase-2 MIN-merge of tentative distances; ``prev`` is the
    replicated-consistent post-last-sync buffer (the sparse reference)."""
    if cfg.sync == "butterfly":
        return collectives.butterfly_reduce(
            new, cfg.axes, mono.MIN_U32, fanout=cfg.fanout
        )
    if cfg.sync == "sparse":
        return collectives.butterfly_reduce_sparse(
            new, cfg.axes, mono.MIN_U32, fanout=cfg.fanout,
            capacity=capacity, ref=prev,
        )
    if cfg.sync == "adaptive":
        return collectives.butterfly_reduce_adaptive(
            new, cfg.axes, mono.MIN_U32, fanout=cfg.fanout,
            capacity=capacity, density_threshold=cfg.density_threshold,
            ref=prev,
        )
    if cfg.sync == "all_to_all":
        return collectives.all_to_all_merge(new, cfg.axes, op=jnp.minimum)
    if cfg.sync == "xla":
        out = new
        for a in cfg.axes:
            out = lax.pmin(out, a)
        return out
    raise ValueError(f"unknown sync {cfg.sync!r}")


def build_sssp_fn(
    pg: PartitionedGraph, mesh: jax.sharding.Mesh, cfg: SSSPConfig,
    *, trace: bool = False, trace_levels=None,
):
    """Compile-ready distributed SSSP.

    Returns ``run(arrays, root)`` where ``arrays`` is the placed WEIGHTED
    graph pytree and ``root`` a replicated int32 scalar.  Output: per-device
    owned distances ``uint32[P, vmax]`` (:data:`UNREACHED` sentinel),
    iterations executed, and edges relaxed (the honest-TEPS analogue).

    ``trace=True`` appends the §18 flight-recorder buffer
    ``int32[P, trace_levels, TRACE_COLS]``: WORDS/SHIPPED are
    changed-vs-reference distance words (the MIN-monoid sparse driver),
    POP counts distances improved per iteration, DIR is always 0.
    ``trace=False`` stages the exact uninstrumented program.
    """
    if pg.edge_weight is None:
        raise ValueError(
            "SSSP requires a weighted partition — generate the graph with "
            "max_weight > 0 (graph.generators) or pass weights to from_edges"
        )
    n_rows = dist_rows(pg)
    nw = n_rows // fr.WORD_BITS
    vmax = pg.vmax
    capacity = cfg.resolved_capacity(n_rows)
    # Bucket advances consume iterations without relaxing; bound generously.
    max_iters = cfg.max_iters if cfg.max_iters is not None else (1 << 30)
    spec = P(cfg.axes if len(cfg.axes) > 1 else cfg.axes[0])
    inf = jnp.uint32(UNREACHED)
    if trace:
        from repro.core import flightrec

        t_levels = flightrec.resolve_trace_levels(trace_levels, max_iters)

    def body(arrays, root):
        arrays = jax.tree.map(lambda a: a[0], arrays)
        v_start = arrays["v_start"]
        src, dst = arrays["edge_src"], arrays["edge_dst"]
        w = arrays["edge_weight"].astype(jnp.uint32)
        emask = jnp.arange(src.shape[0], dtype=jnp.int32) < arrays["edge_count"]

        dist = jnp.full((n_rows,), inf, jnp.uint32).at[root].set(0)
        changed = fr.set_bit(jnp.zeros((nw,), jnp.uint32), root)

        def cond(state):
            dist, changed, bucket, it, relaxed = state[:5]
            return (fr.popcount(changed) > 0) & (it < max_iters)

        def step(state):
            dist, changed, bucket, it, relaxed = state[:5]

            # -- bucket frontier selection (delta-stepping-style) ---------
            if cfg.delta:
                limit = (bucket + 1) * jnp.uint32(cfg.delta)
                active = fr.pack(fr.unpack(changed) & (dist < limit))
                # nothing below the bucket limit: advance the bucket and
                # run an (empty) round — dist/changed are untouched.
                bucket = jnp.where(fr.popcount(active) > 0, bucket, bucket + 1)
            else:
                active = changed

            # -- Phase 1: relax owned out-edges of active sources ---------
            src_active = fr.get_bits(active, src) & emask
            ds = dist[src]
            nd = ds + w  # uint32; nd < ds detects wraparound -> saturate
            cand = jnp.where(src_active & (ds != inf) & (nd >= ds), nd, inf)
            relaxed_local = dist.at[dst].min(cand)

            # -- Phase 2: butterfly MIN synchronization -------------------
            if trace:
                t_words, t_branch, t_shipped = flightrec.monoid_sync_stats(
                    relaxed_local, dist, cfg, capacity
                )
            synced = _sync_dist(relaxed_local, dist, cfg, capacity)

            # -- changed-vertex frontier update ---------------------------
            improved = fr.pack(synced < dist)
            changed = (changed & ~active) | improved

            out = (
                synced,
                changed,
                bucket,
                it + 1,
                relaxed + src_active.sum(dtype=jnp.float32),
            )
            if not trace:
                return out, None
            row = flightrec.trace_row(
                it, t_words, fr.popcount(improved), jnp.int32(0),
                t_branch, t_shipped, fr.changed_count(synced, dist),
            )
            return out, (it, row)

        init = (dist, changed, jnp.uint32(0), jnp.int32(0), jnp.float32(0))
        state = loop.traced_while(
            cond, step, init, trace=trace,
            trace_levels=t_levels if trace else None,
        )
        dist, changed, _, it, relaxed = state[:5]
        total_relaxed = lax.psum(relaxed, cfg.axes)
        d_owned = lax.dynamic_slice(dist, (v_start,), (vmax,))
        out = (d_owned[None], it[None], total_relaxed[None])
        if trace:
            out = out + (state[5][None],)
        return out

    return loop.jit_shard(body, mesh, graph_array_keys(pg), spec, trace=trace)


def assemble_distances(pg: PartitionedGraph, d_owned: np.ndarray) -> np.ndarray:
    """``d_owned [P, vmax]`` -> global ``int64[n]`` (:data:`UNREACHED`
    sentinel preserved)."""
    d_owned = np.asarray(d_owned)
    dist = np.full(pg.n, UNREACHED, dtype=np.int64)
    for i in range(pg.p):
        s, c = int(pg.v_start[i]), int(pg.v_count[i])
        dist[s : s + c] = d_owned[i, :c]
    return dist


def distributed_sssp(
    pg: PartitionedGraph,
    mesh: jax.sharding.Mesh,
    root: int,
    cfg: SSSPConfig = SSSPConfig(),
) -> Tuple[np.ndarray, int, float]:
    """End-to-end helper: place arrays, run, assemble global distances."""
    arrays = place_arrays(pg, mesh, cfg.axes)
    fn = build_sssp_fn(pg, mesh, cfg)
    d_owned, iters, relaxed = fn(arrays, jnp.int32(root))
    return (
        assemble_distances(pg, d_owned),
        int(np.max(iters)),
        float(np.asarray(relaxed)[0]),
    )
