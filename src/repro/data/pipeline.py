"""Deterministic synthetic data pipeline.

Framework requirements it satisfies:

* **sharded**: each data-parallel host slice draws its own batch shard from
  a per-(step, shard) seeded generator — no cross-host coordination;
* **restart-deterministic**: ``batch_at(step)`` is a pure function of
  (seed, step), so checkpoint/restart resumes the exact stream with no
  state to save (fault-tolerance requirement: deterministic data-skip);
* **self-supervised structure**: token streams are Zipf-distributed with a
  short induction pattern so a real LM loss signal exists (quickstart
  trains to visibly decreasing loss, not noise).

Modality stubs follow the brief: whisper gets frame embeddings, VLM gets
patch embeddings — both synthesized here.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2
    pattern_len: int = 8  # induction: second half of each pattern repeats


class SyntheticLM:
    """batch_at(step) -> {tokens, labels[, frames | patches]}."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dcfg: DataConfig = DataConfig()):
        self.cfg, self.batch, self.seq, self.dcfg = cfg, batch, seq, dcfg

    def _tokens(self, rng: np.random.Generator, n: int, l: int) -> np.ndarray:
        v = self.cfg.vocab
        z = rng.zipf(self.dcfg.zipf_a, size=(n, l)) % (v - 1) + 1
        pl = self.dcfg.pattern_len
        t = z.astype(np.int32)
        # copy each pattern's first half into its second half (induction)
        full = (l // pl) * pl
        view = t[:, :full].reshape(n, -1, pl)
        view[:, :, pl // 2 :] = view[:, :, : pl // 2]
        return t

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict:
        assert self.batch % n_shards == 0
        n = self.batch // n_shards
        rng = np.random.default_rng(
            [self.dcfg.seed, step, shard]
        )
        cfg = self.cfg
        l = self.seq
        out: Dict[str, np.ndarray] = {}
        if cfg.family == "vlm":
            lt = l - cfg.n_patches
            out["patches"] = rng.normal(
                size=(n, cfg.n_patches, cfg.patch_dim)
            ).astype(np.float32)
            t = self._tokens(rng, n, lt + 1)
        elif cfg.family == "audio":
            out["frames"] = rng.normal(
                size=(n, cfg.n_frames, cfg.d_model)
            ).astype(np.float32)
            t = self._tokens(rng, n, l + 1)
        else:
            t = self._tokens(rng, n, l + 1)
        out["tokens"] = t[:, :-1]
        out["labels"] = t[:, 1:].copy()
        return out
