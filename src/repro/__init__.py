"""ButterFly BFS reproduction package.

Importing ``repro`` installs the JAX version-compat shims (see
:mod:`repro.compat`) so every submodule can target the modern JAX API
regardless of the pinned toolchain.
"""

from repro import compat  # noqa: F401  (side effect: installs jax shims)
