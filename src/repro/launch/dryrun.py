import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief deliverable e).

For every (architecture × input shape × mesh): build ShapeDtypeStruct
stand-ins (no allocation), ``jit(step).lower(...).compile()``, print
``memory_analysis()`` + ``cost_analysis()``, extract the three roofline
terms, and write one JSON per cell under ``experiments/dryrun/``.

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch butterfly-bfs --mesh single

The two lines above this docstring MUST stay first: jax locks the device
count on first init, and only the dry-run wants 512 host devices.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import numpy as np


def _cell_record(**kw) -> Dict:
    return dict(kw)


def input_specs(arch: str, shape_name: str, mesh, rules):
    """Brief-named helper: ShapeDtypeStruct stand-ins for every model input
    of this (arch, shape) cell — weak-type-correct, shardable, no device
    allocation."""
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.dist import sharding as shd
    from repro.models import api

    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    out = {
        "inputs": shd.tree_structs(api.input_defs(cfg, shape), cfg.compute_dtype, rules, mesh)
    }
    if shape.kind == "decode":
        out["cache"] = shd.tree_structs(
            api.cache_defs(cfg, shape), cfg.compute_dtype, rules, mesh
        )
    return out


def _parse_overrides(s: Optional[str]) -> Dict:
    """--override 'ring_local_cache=True,train_microbatches=8'"""
    out = {}
    if not s:
        return out
    for kv in s.split(","):
        k, v = kv.split("=")
        out[k.strip()] = eval(v)  # noqa: S307 — trusted CLI input
    return out


def run_lm_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: str,
    *,
    grad_sync: str = "xla",
    fanout: int = 2,
    overrides: Optional[Dict] = None,
    tag_suffix: str = "",
    analysis: bool = True,
    verbose: bool = True,
) -> Dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.configs.base import SHAPES, shape_supported
    from repro.dist import sharding as shd
    from repro.dist.sharding import rules_for_mesh
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh
    from repro.models import api
    from repro.train import optim
    from repro.train import step as step_mod

    import dataclasses as _dc

    cfg = _dc.replace(configs.get_config(arch), scan_unroll=True,
                      **(overrides or {}))
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}" + (f"__{tag_suffix}" if tag_suffix else "")
    ok, reason = shape_supported(cfg, shape)
    rec = _cell_record(
        arch=arch, shape=shape_name, mesh=mesh_name, kind=shape.kind,
        grad_sync=grad_sync, overrides=overrides or {}, tag=tag_suffix,
        status="skip" if not ok else "pending",
    )
    if not ok:
        rec["skip_reason"] = reason
        _write(out_dir, mesh_name, tag, rec)
        if verbose:
            print(f"[{mesh_name}] {tag}: SKIP ({reason.split(':')[0]})")
        return rec

    try:
        from repro.launch import analytic, corrections as corr

        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(list(mesh.shape.values())))
        rules = rules_for_mesh(mesh, cfg.fsdp and grad_sync == "xla")
        repl = NamedSharding(mesh, P())
        scalar = jax.ShapeDtypeStruct((), np.int32, sharding=repl)

        def build(c):
            pdefs = api.param_defs(c)
            params = shd.tree_structs(pdefs, c.param_dtype, rules, mesh)
            if shape.kind == "train":
                opt_defs = optim.get(c.optimizer).state_defs(pdefs)
                opt_state = shd.tree_structs(opt_defs, "float32", rules, mesh)
                batch = shd.tree_structs(
                    api.input_defs(c, shape), c.compute_dtype, rules, mesh
                )
                # microbatching is a runtime-memory knob; per-step flop
                # totals are identical, so the analysis compile uses mb=1
                # (base.py `train_microbatches` docstring)
                mb = 1 if c.scan_unroll else c.train_microbatches
                if grad_sync == "xla":
                    fn = step_mod.build_train_step(
                        c, mesh=mesh, rules=rules, microbatches=mb
                    )
                else:
                    fn = step_mod.build_train_step_butterfly(
                        c, mesh, rules, method=grad_sync, fanout=fanout,
                        microbatches=mb,
                    )
                return jax.jit(fn, donate_argnums=(0, 1)), (
                    params, opt_state, batch, scalar,
                )
            if shape.kind == "prefill":
                batch = shd.tree_structs(
                    api.input_defs(c, shape), c.compute_dtype, rules, mesh
                )
                return jax.jit(api.prefill_fn(c, rules, mesh)), (params, batch)
            cache = shd.tree_structs(
                api.cache_defs(c, shape), c.compute_dtype, rules, mesh
            )
            ins = shd.tree_structs(
                api.input_defs(c, shape), c.compute_dtype, rules, mesh
            )
            return (
                jax.jit(api.decode_fn(c, rules, mesh), donate_argnums=(1,)),
                (params, cache, ins["token"], ins["pos"]),
            )

        # --- compile 1: RUNTIME config (scans) -> memory fit + step compile
        import dataclasses as _dc2

        run_cfg = _dc2.replace(cfg, scan_unroll=False)
        t0 = time.time()
        jfn, args = build(run_cfg)
        compiled_run = jfn.lower(*args).compile()
        t_run = time.time() - t0
        mem = hlo_stats.memory_stats(compiled_run)
        mem_print = compiled_run.memory_analysis()
        ca_run = compiled_run.cost_analysis() or {}
        # runtime collectives: per-microbatch FSDP gathers etc. live inside
        # the microbatch scan (counted once; × microbatches at runtime) —
        # recorded for the §Perf grad-accum/FSDP coupling analysis
        cstats_run = hlo_stats.collective_stats(compiled_run.as_text())
        if not analysis:
            # compile-proof mode (multi-pod mesh): the roofline table is
            # single-pod per the brief; one runtime compile proves the
            # sharding + records memory.
            rec.update(
                status="ok", chips=chips, analysis=False,
                compile_runtime_cfg_s=round(t_run, 1),
                memory=mem,
                collectives_runtime=cstats_run,
                flops_per_device_raw=float(ca_run.get("flops", 0.0)),
            )
            if verbose:
                print(f"[{mesh_name}] {tag}: OK (compile-proof) "
                      f"compile={t_run:.0f}s "
                      f"mem/dev={mem['peak_bytes_per_device']/2**30:.2f}GiB")
                print("  memory_analysis:", mem_print)
            del compiled_run
            _write(out_dir, mesh_name, tag, rec)
            return rec
        del compiled_run

        # --- compile 2: ANALYSIS config (unrolled) -> flops + collectives
        t0 = time.time()
        jfn, args = build(cfg)
        compiled = jfn.lower(*args).compile()
        t_compile = time.time() - t0
        hlo = compiled.as_text()
        _save_hlo(out_dir, mesh_name, tag, hlo)
        ca = compiled.cost_analysis() or {}
        cstats = hlo_stats.collective_stats(hlo)
        wire_b = sum(v["wire_bytes"] for v in cstats.values())
        op_b = sum(v["operand_bytes"] for v in cstats.values())
        c = corr.prefill_corrections(cfg, shape)
        flops_dev = hlo_stats.dot_flops(hlo) + c["flops"] / chips
        ana = analytic.step_bytes(cfg, shape)
        bytes_dev = ana["global"] / chips
        t_compute = flops_dev / hlo_stats.PEAK_FLOPS
        t_memory = bytes_dev / hlo_stats.HBM_BW
        t_coll = wire_b / hlo_stats.ICI_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        mf = api.model_flops(cfg, shape)
        counts = api.param_counts(cfg)
        hlo_flops_global = flops_dev * chips
        rec.update(
            status="ok",
            chips=chips,
            compile_s=round(t_compile, 1),
            compile_runtime_cfg_s=round(t_run, 1),
            memory=mem,
            flops_per_device=flops_dev,
            flops_per_device_raw=float(ca.get("flops", 0.0)),
            bytes_per_device=bytes_dev,
            bytes_per_device_raw=float(ca.get("bytes accessed", 0.0)),
            collective_operand_bytes=op_b,
            collective_wire_bytes=wire_b,
            collectives=cstats,
            collectives_runtime=cstats_run,
            runtime_microbatches=(
                run_cfg.train_microbatches if shape.kind == "train" else 1
            ),
            t_compute=t_compute,
            t_memory=t_memory,
            t_collective=t_coll,
            dominant=dominant,
            step_time_est=step_time,
            model_flops=mf,
            params_total=counts["total"],
            params_active=counts["active"],
            useful_flops_ratio=(mf / hlo_flops_global) if hlo_flops_global else 0.0,
            roofline_fraction=(
                (mf / chips / hlo_stats.PEAK_FLOPS) / step_time
                if step_time > 0
                else 0.0
            ),
        )
        if verbose:
            print(f"[{mesh_name}] {tag}: OK compile={t_run:.0f}s+{t_compile:.0f}s "
                  f"mem/dev={mem['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"dom={dominant} "
                  f"t=({t_compute*1e3:.1f},{t_memory*1e3:.1f},"
                  f"{t_coll*1e3:.1f})ms "
                  f"MF/HLO={rec['useful_flops_ratio']:.2f} "
                  f"roofline={rec['roofline_fraction']*100:.1f}%")
            print("  memory_analysis:", mem_print)
            print("  cost_analysis: dot_flops=%.3e raw_flops=%.3e raw_bytes=%.3e"
                  % (flops_dev, ca.get("flops", 0), ca.get("bytes accessed", 0)))
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{mesh_name}] {tag}: FAIL {type(e).__name__}: {str(e)[:300]}")
    _write(out_dir, mesh_name, tag, rec)
    return rec


def run_bfs_cell(
    multi_pod: bool,
    out_dir: str,
    *,
    scale: int = 29,
    edge_factor: int = 8,
    fanout: int = 4,
    sync: str = "butterfly",
    verbose: bool = True,
) -> Dict:
    """The paper's own workload on the production mesh: distributed BFS with
    butterfly frontier synchronization over all mesh axes."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import bfs
    from repro.graph.partition import synthetic_shapes
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh

    mesh_name = "multi" if multi_pod else "single"
    tag = f"butterfly-bfs__kron{scale}_ef{edge_factor}_f{fanout}_{sync}"
    rec = _cell_record(
        arch="butterfly-bfs", shape=f"kron{scale}_ef{edge_factor}",
        mesh=mesh_name, kind="bfs", sync=sync, fanout=fanout, status="pending",
    )
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        axes = tuple(mesh.axis_names)
        chips = int(np.prod(list(mesh.shape.values())))
        shapes = synthetic_shapes(1 << scale, 2 * (1 << scale) * edge_factor, chips)
        cfg = bfs.BFSConfig(axes=axes, fanout=fanout, sync=sync,
                            mode="top_down", max_levels=64)
        spec = P(axes if len(axes) > 1 else axes[0])
        sh = NamedSharding(mesh, spec)
        arrays = {
            k: jax.ShapeDtypeStruct(v, np.int32, sharding=sh)
            for k, v in shapes.array_shapes().items()
        }
        root = jax.ShapeDtypeStruct((), np.int32, sharding=NamedSharding(mesh, P()))
        t0 = time.time()
        fn = bfs.build_bfs_fn(shapes, mesh, cfg)
        lowered = fn.lower(arrays, root)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = hlo_stats.memory_stats(compiled)
        hlo = compiled.as_text()
        _save_hlo(out_dir, mesh_name, tag, hlo)
        roof = hlo_stats.roofline_from(compiled, hlo)
        rec.update(
            status="ok", chips=chips,
            n_vertices=shapes.n, n_edges=shapes.n_edges,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=mem,
            flops_per_device=roof.flops_per_device,
            bytes_per_device=roof.bytes_per_device,
            collective_operand_bytes=roof.collective_operand_bytes,
            collective_wire_bytes=roof.collective_wire_bytes,
            collectives=hlo_stats.collective_stats(hlo),
            t_compute=roof.t_compute, t_memory=roof.t_memory,
            t_collective=roof.t_collective, dominant=roof.dominant,
        )
        if verbose:
            print(f"[{mesh_name}] {tag}: OK compile={t_compile:.0f}s "
                  f"mem/dev={mem['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"dom={roof.dominant}")
            print("  memory_analysis:", compiled.memory_analysis())
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{mesh_name}] {tag}: FAIL {type(e).__name__}: {str(e)[:300]}")
    _write(out_dir, mesh_name, tag, rec)
    return rec


def _write(out_dir: str, mesh_name: str, tag: str, rec: Dict) -> None:
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def _save_hlo(out_dir: str, mesh_name: str, tag: str, hlo: str) -> None:
    """Persist the optimized HLO (gzip) so roofline parsers can be re-run
    without recompiling (launch/reroof.py)."""
    import gzip

    d = os.path.join(out_dir, mesh_name, "hlo")
    os.makedirs(d, exist_ok=True)
    with gzip.open(os.path.join(d, f"{tag}.hlo.gz"), "wt") as f:
        f.write(hlo)


def main(argv=None) -> int:
    from repro import configs
    from repro.configs.base import SHAPES

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id | all | butterfly-bfs (comma-separated ok)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--grad-sync", default="xla",
                    choices=["xla", "butterfly", "rabenseifner", "all_to_all"])
    ap.add_argument("--fanout", type=int, default=2)
    ap.add_argument("--bfs-scale", type=int, default=29)
    ap.add_argument("--bfs-ef", type=int, default=8)
    ap.add_argument("--bfs-sync", default="butterfly")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default=None,
                    help="ModelConfig overrides, e.g. 'ring_local_cache=True'")
    ap.add_argument("--tag", default="",
                    help="suffix for the output file (perf variants)")
    ap.add_argument("--no-analysis", action="store_true",
                    help="compile-proof only (skip the unrolled analysis "
                         "compile; used for the multi-pod mesh)")
    args = ap.parse_args(argv)
    overrides = _parse_overrides(args.override)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = (
        configs.ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    )
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")

    n_fail = 0
    for mp in meshes:
        mesh_name = "multi" if mp else "single"
        for arch in archs:
            if arch == "butterfly-bfs":
                rec = run_bfs_cell(
                    mp, args.out, scale=args.bfs_scale, edge_factor=args.bfs_ef,
                    fanout=args.fanout, sync=args.bfs_sync,
                )
                n_fail += rec["status"] == "fail"
                continue
            for shp in shapes:
                fname = f"{arch}__{shp}" + (f"__{args.tag}" if args.tag else "")
                tagfile = os.path.join(args.out, mesh_name, f"{fname}.json")
                if args.skip_existing and os.path.exists(tagfile):
                    try:
                        st = json.load(open(tagfile)).get("status")
                    except Exception:
                        st = None
                    if st in ("ok", "skip"):
                        print(f"[{mesh_name}] {arch}__{shp}: cached ({st})")
                        continue
                rec = run_lm_cell(
                    arch, shp, mp, args.out,
                    grad_sync=args.grad_sync, fanout=args.fanout,
                    overrides=overrides, tag_suffix=args.tag,
                    analysis=not args.no_analysis,
                )
                n_fail += rec["status"] == "fail"
    print(f"dry-run done; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
