"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSONs written by launch.dryrun.

    PYTHONPATH=src python -m repro.launch.summary [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

V5E_HBM = 16 * 2**30

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str, mesh: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, mesh, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def _key(r):
    try:
        si = SHAPE_ORDER.index(r["shape"])
    except ValueError:
        si = 99
    return (r["arch"], si)


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(recs: List[Dict]) -> str:
    hdr = (f"| {'arch':21s} | {'shape':11s} | {'t_comp ms':>9s} | "
           f"{'t_mem ms':>8s} | {'t_coll ms':>9s} | {'dom':10s} | "
           f"{'MF/HLO':>6s} | {'roofline %':>10s} | note |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in hdr.split("|")[1:-1]) + "|"
    rows = [hdr, sep]
    for r in sorted(recs, key=_key):
        if r.get("kind") == "bfs":
            continue
        if r["status"] == "skip":
            rows.append(
                f"| {r['arch']:21s} | {r['shape']:11s} | {'—':>9s} | {'—':>8s} "
                f"| {'—':>9s} | {'skip':10s} | {'—':>6s} | {'—':>10s} | "
                f"{r['skip_reason'].split(':')[0]} |")
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']:21s} | {r['shape']:11s} | FAIL: "
                f"{r.get('error','?')[:60]} |")
            continue
        note = ""
        if r["memory"]["peak_bytes_per_device"] > V5E_HBM:
            note = f"OVER 16GiB ({fmt_bytes(r['memory']['peak_bytes_per_device'])}GiB)"
        rows.append(
            f"| {r['arch']:21s} | {r['shape']:11s} "
            f"| {r['t_compute']*1e3:9.1f} | {r['t_memory']*1e3:8.1f} "
            f"| {r['t_collective']*1e3:9.2f} | {r['dominant']:10s} "
            f"| {r['useful_flops_ratio']:6.2f} "
            f"| {r['roofline_fraction']*100:10.1f} | {note} |")
    return "\n".join(rows)


def dryrun_table(recs: List[Dict]) -> str:
    hdr = (f"| {'arch':21s} | {'shape':11s} | {'status':6s} | "
           f"{'mem/dev GiB':>11s} | {'fits v5e':8s} | {'compile s':>9s} | "
           f"{'coll ops (ar/ag/rs/a2a/cp)':26s} |")
    sep = "|" + "|".join("-" * (len(c) + 2) for c in hdr.split("|")[1:-1]) + "|"
    rows = [hdr, sep]
    for r in sorted(recs, key=_key):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']:21s} | {r['shape']:11s} | skip   "
                        f"| {'—':>11s} | {'—':8s} | {'—':>9s} | {'—':26s} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']:21s} | {r['shape']:11s} | FAIL |")
            continue
        mem = r["memory"]["peak_bytes_per_device"]
        c = r.get("collectives", {})
        ops = "/".join(
            str(int(c.get(k, {}).get("count", 0)))
            for k in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")
        )
        comp = r.get("compile_s", 0) + r.get("compile_runtime_cfg_s", 0)
        rows.append(
            f"| {r['arch']:21s} | {r['shape']:11s} | ok     "
            f"| {fmt_bytes(mem):>11s} | {'YES' if mem <= V5E_HBM else 'NO':8s} "
            f"| {comp:9.0f} | {ops:26s} |")
    return "\n".join(rows)


def bfs_table(recs: List[Dict]) -> str:
    rows = ["| run | chips | mem/dev GiB | t_comp ms | t_mem ms | t_coll ms |"
            " dom | permutes/level |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["mesh"], str(r.get("fanout")))):
        if r.get("kind") != "bfs" or r["status"] != "ok":
            continue
        c = r.get("collectives", {})
        rows.append(
            f"| kron29 {r.get('sync')} f={r.get('fanout')} ({r['mesh']}) "
            f"| {r['chips']} | {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
            f"| {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
            f"| {r['t_collective']*1e3:.2f} | {r['dominant']} "
            f"| {int(c.get('collective-permute', {}).get('count', 0))} |")
    return "\n".join(rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    for mesh in ("single", "multi"):
        recs = load(args.dir, mesh)
        if not recs:
            continue
        lm = [r for r in recs if r.get("kind") != "bfs"]
        bfs = [r for r in recs if r.get("kind") == "bfs"]
        n_ok = sum(r["status"] == "ok" for r in recs)
        n_skip = sum(r["status"] == "skip" for r in recs)
        n_fail = sum(r["status"] == "fail" for r in recs)
        print(f"\n##### mesh={mesh}: {n_ok} ok, {n_skip} skip, {n_fail} fail\n")
        print("### Dry-run\n")
        print(dryrun_table(lm))
        if mesh == "single":
            print("\n### Roofline\n")
            print(roofline_table(lm))
        if bfs:
            print("\n### BFS cells (per-level terms)\n")
            print(bfs_table(bfs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
