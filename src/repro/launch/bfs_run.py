"""Distributed ButterFly BFS launcher (the paper's workload, end to end).

``python -m repro.launch.bfs_run --scale 16 --devices 8 --fanout 4``

Generates a Kronecker graph, 1D-partitions it over simulated devices,
runs BFS from random roots with the paper's benchmarking protocol
(100 roots, trim fastest/slowest 25%) and reports GTEP/s.

``--num-sources B`` (B > 1) switches to the bit-parallel multi-source
engine (DESIGN.md §13): the ``--roots`` queries are packed into B-lane
waves and the report adds aggregate searches/s.

``--algo sssp`` runs weighted single-source shortest paths (butterfly
min-reduce; requires ``--max-weight``, defaulted when omitted) and
``--algo bc`` runs Brandes betweenness centrality waves over the root
queries (DESIGN.md §14).

``--algo {pagerank,cc,tri,kcore}`` runs a §19 vertex program (root-free
global analytics) on the same butterfly exchange: the run reports rounds,
edge-examination rate, and an algo-specific summary (top ranks / component
count / triangle total / degeneracy); ``--trace`` exports the convergence
flight-recorder rows (POP column = residual ppm, labels changed, or peel
count — see ``repro.core.flightrec``).

``--stats-json PATH`` dumps the run's ``EngineStats`` (plus graph/config
identity and wall timing) as machine-readable JSON — the serving CLI
(``repro.launch.serve_graph``) emits the same schema extended with service
telemetry (DESIGN.md §15).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

STATS_SCHEMA = "bfs_run_stats/v1"


def write_stats_json(path, *, algo, graph, devices, config, timing_ms,
                     engine_stats, **extra) -> None:
    """Persist one run's machine-readable stats (schema asserted by the
    smoke test; ``serve_graph`` adds a ``telemetry`` extra)."""
    doc = {
        "schema": STATS_SCHEMA,
        "algo": algo,
        "graph": graph,
        "devices": devices,
        "config": config,
        "timing_ms": timing_ms,
        "engine_stats": (
            dataclasses.asdict(engine_stats)
            if dataclasses.is_dataclass(engine_stats) else engine_stats
        ),
    }
    doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", default="kronecker",
                    choices=["kronecker", "urand", "torus"])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--fanout", type=int, default=4)
    ap.add_argument("--sync", default="butterfly",
                    choices=["butterfly", "sparse", "adaptive", "rabenseifner",
                             "all_to_all", "xla"])
    ap.add_argument("--sparse-capacity", type=int, default=0,
                    help="first-round (word,idx)-pair capacity of the sparse "
                         "sync; 0 = auto (n_words//64)")
    ap.add_argument("--density-threshold", type=float, default=0.02,
                    help="adaptive sync: go sparse while max popcount <= "
                         "threshold * bitmap bits")
    ap.add_argument("--mode", default="top_down",
                    choices=["top_down", "bottom_up", "direction_optimizing"])
    ap.add_argument("--algo", default="bfs",
                    choices=["bfs", "sssp", "bc",
                             "pagerank", "cc", "tri", "kcore"],
                    help="traversal workload (bfs/sssp/bc) or §19 vertex "
                         "program (pagerank, connected components, triangle "
                         "counting, k-core decomposition)")
    ap.add_argument("--max-weight", type=int, default=0,
                    help="uint32 edge weights in [1, max-weight]; 0 = "
                         "unweighted (sssp defaults to 64)")
    ap.add_argument("--delta", type=int, default=0,
                    help="sssp bucket width (delta-stepping-style); 0 = "
                         "level-synchronous relaxation")
    ap.add_argument("--roots", type=int, default=16,
                    help="number of root queries to run")
    ap.add_argument("--num-sources", type=int, default=1,
                    help="BFS lanes per wave: 1 = classic single-source; "
                         ">1 packs the root queries into bit-parallel "
                         "multi-source waves (analytics.msbfs)")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--updates", default=None, metavar="FILE",
                    help="replay a recorded JSONL edge-update stream "
                         "(serve_graph --record-updates) through the §16 "
                         "delta overlay + partition patch before measuring")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump EngineStats + run identity as JSON")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export the §18 per-level flight-recorder trace "
                         "of one traversal (first root) as Perfetto/Chrome "
                         "trace_event JSON; --algo bfs additionally "
                         "host-times every level so spans carry real "
                         "durations")
    ap.add_argument("--profile", default=None, metavar="FILE", nargs="?",
                    const="-",
                    help="run the §20 cost-model profiler on the compiled "
                         "single-source BFS program: reconcile analytic "
                         "sync bytes against the compiled HLO, report "
                         "achieved-vs-modeled GTEPS and the per-level "
                         "time×bytes table; FILE (optional) also receives "
                         "the profile as JSON")
    args = ap.parse_args(argv)
    if args.trace and args.pallas:
        ap.error("--trace instruments the XLA path; drop --pallas")
    if args.profile and args.pallas:
        ap.error("--profile times the XLA path; drop --pallas")
    if args.profile and args.algo != "bfs":
        ap.error("--profile profiles the single-source BFS program; "
                 "use --algo bfs")

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )
    import time

    import jax
    import numpy as np

    from repro.core import bfs
    from repro.graph import csr, generators, partition

    max_weight = args.max_weight
    if args.algo == "sssp" and not max_weight:
        max_weight = 64
    if args.graph == "kronecker":
        g = generators.kronecker(args.scale, args.edge_factor, seed=args.seed,
                                 max_weight=max_weight)
    elif args.graph == "urand":
        g = generators.uniform_random(
            1 << args.scale, (1 << args.scale) * args.edge_factor,
            seed=args.seed, max_weight=max_weight,
        )
    else:
        g = generators.torus_2d(1 << (args.scale // 2), max_weight=max_weight,
                                seed=args.seed)
    print(f"graph: n={g.n:,} m={g.n_edges:,} (directed, symmetrized"
          f"{', weighted' if g.weighted else ''})")
    pg = partition.partition_1d(g, args.devices)
    if args.updates:
        from repro.dynamic import delta as delta_mod

        overlay = delta_mod.DeltaOverlay(g)
        n_ins = n_del = n_comp = 0
        for batch in delta_mod.read_update_stream(args.updates):
            if g.weighted and batch.insert_weights is None:
                # replaying an unweighted stream onto a weighted graph:
                # unit weights keep the stream applicable
                batch = delta_mod.EdgeBatch(
                    insert_src=batch.insert_src,
                    insert_dst=batch.insert_dst,
                    insert_weights=np.ones(batch.insert_src.size, np.uint32),
                    delete_src=batch.delete_src,
                    delete_dst=batch.delete_dst,
                )
            update = overlay.apply(batch)
            n_ins += update.ins_src.size
            n_del += update.del_src.size
            if (not delta_mod.apply_update_to_partition(pg, update)
                    or overlay.needs_compaction()):
                pg = partition.partition_1d(overlay.compact(), args.devices)
                n_comp += 1
        g = overlay.current_graph()
        print(f"replayed updates: {n_ins} directed inserts, {n_del} "
              f"deletes, {n_comp} compactions -> m={g.n_edges:,}")
    mesh = jax.make_mesh((args.devices,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = bfs.BFSConfig(
        axes=("data",), fanout=args.fanout, sync=args.sync, mode=args.mode,
        use_pallas=args.pallas, sparse_capacity=args.sparse_capacity,
        density_threshold=args.density_threshold,
    )
    rng = np.random.default_rng(args.seed)
    # DISTINCT roots (clamped to the big component): engine waves fold
    # duplicate roots (DESIGN.md §15), so sampling with replacement would
    # silently under-count the work behind the reported rates
    roots = csr.largest_component_roots(g, args.roots, rng).tolist()
    n_roots = len(roots)

    graph_doc = {"name": args.graph, "scale": args.scale,
                 "edge_factor": args.edge_factor, "n": g.n,
                 "n_real": g.n_real, "n_edges": g.n_edges,
                 "weighted": bool(g.weighted)}
    config_doc = {"sync": args.sync, "mode": args.mode,
                  "fanout": args.fanout, "lanes": args.num_sources,
                  "delta": args.delta, "max_weight": max_weight,
                  "use_pallas": bool(args.pallas)}

    def emit_profile(report: dict) -> None:
        """Print the §20 profile table (+ cached-program reconciliation)
        and optionally persist the whole report as JSON."""
        prof = report["program"]
        print()
        print(prof.table())
        for ent in report.get("cache", []):
            verdict = ("reconciled" if ent.reconciled else
                       "MISMATCH" if ent.supported else "unsupported")
            print(f"cached {ent.algo} sync={ent.sync} "
                  f"lanes={ent.lanes} n_words={ent.n_words}: {verdict}")
        if args.profile != "-":
            doc = {"schema": "bfs_profile/v1",
                   "program": prof.to_dict(),
                   "cache": [e.to_dict() for e in report.get("cache", [])]}
            with open(args.profile, "w") as f:
                json.dump(doc, f, indent=1)
            print(f"profile -> {args.profile}")

    def export_trace(trace) -> dict:
        """Write the Perfetto doc and return the JSON trace table (lands
        in --stats-json as a ``trace`` extra)."""
        from repro.core import flightrec

        doc = flightrec.trace_chrome_doc(trace)
        with open(args.trace, "w") as f:
            json.dump(doc, f, indent=1)
        s = trace.summary()
        print(f"trace: {s['levels']} levels ({s['dense_levels']} dense / "
              f"{s['sparse_levels']} sparse / {s['fallback_levels']} "
              f"fallback), {s['bytes_per_node_total']:.0f} sync B/node "
              f"-> {args.trace}")
        return trace.to_dict()

    if args.algo == "sssp":
        from repro.traversal import sssp as sssp_mod

        if args.sync not in sssp_mod.SYNCS:
            ap.error(f"--algo sssp supports --sync {sssp_mod.SYNCS}, "
                     f"got {args.sync!r}")
        scfg = sssp_mod.SSSPConfig(
            axes=("data",), fanout=args.fanout, sync=args.sync,
            delta=args.delta, sparse_capacity=args.sparse_capacity,
            density_threshold=args.density_threshold,
        )
        arrays = bfs.place_arrays(pg, mesh, scfg.axes)
        fn = sssp_mod.build_sssp_fn(pg, mesh, scfg)
        d, it, relaxed = fn(arrays, np.int32(roots[0]))  # warmup / compile
        jax.block_until_ready(d)
        times, rates, relaxed_total = [], [], 0.0
        for r in roots:
            t0 = time.time()
            d, it, relaxed = fn(arrays, np.int32(r))
            jax.block_until_ready(d)
            dt = time.time() - t0
            times.append(dt)
            rates.append(float(relaxed[0]) / dt / 1e9)
            relaxed_total += float(relaxed[0])
        t = np.array(times)
        print(
            f"SSSP {scfg.sync} fanout={args.fanout} delta={args.delta} "
            f"devices={args.devices}: time {t.mean()*1e3:.1f}ms  "
            f"GRelax/s {np.mean(rates):.4f} (host-simulated devices)"
        )
        trace_doc = None
        if args.trace:
            from repro.core import flightrec

            n_rows = sssp_mod.dist_rows(pg)
            tfn = sssp_mod.build_sssp_fn(pg, mesh, scfg, trace=True)
            _, _, _, buf = tfn(arrays, np.int32(roots[0]))
            trace_doc = export_trace(flightrec.TraversalTrace.from_buffer(
                np.asarray(buf), algo="sssp", sync=scfg.sync, p=pg.p,
                fanout=scfg.fanout, n_words=n_rows,
                capacity=scfg.resolved_capacity(n_rows),
                density_threshold=scfg.density_threshold,
            ))
        if args.stats_json:
            from repro.analytics.engine import EngineStats

            write_stats_json(
                args.stats_json, algo="sssp", graph=graph_doc,
                devices=args.devices, config=config_doc,
                timing_ms={"mean": float(t.mean() * 1e3),
                           "total": float(t.sum() * 1e3)},
                engine_stats=EngineStats(
                    sssp_queries=len(roots), relaxed_edges=relaxed_total
                ),
                **({"trace": trace_doc} if trace_doc else {}),
            )
        return 0

    if args.algo == "bc":
        from repro.analytics.engine import BFSQueryEngine

        lanes = max(args.num_sources, 1)
        eng = BFSQueryEngine(pg, mesh, cfg, lanes=lanes)
        eng.betweenness(roots[:lanes])  # warmup / compile
        t0 = time.time()
        bc_scores = eng.betweenness(np.asarray(roots, np.int32))
        dt = time.time() - t0
        top = np.argsort(bc_scores)[::-1][:5]
        print(
            f"BC {args.sync} fanout={args.fanout} devices={args.devices} "
            f"lanes={lanes}: {n_roots} sources in {dt*1e3:.1f}ms "
            f"({n_roots/dt:.1f} sources/s; host-simulated devices)"
        )
        print("top-5 central vertices:",
              ", ".join(f"{v}={bc_scores[v]:.1f}" for v in top))
        trace_doc = None
        if args.trace:
            from repro.analytics import msbfs as ms
            from repro.core import flightrec
            from repro.traversal import bc as bc_mod

            # flattened lane-word buffer the forward-wave sync exchanges
            n_flat = ms.wave_rows(pg) * ms.lane_words(lanes)
            arrays = bfs.place_arrays(pg, mesh, cfg.axes)
            tfn = bc_mod.build_bc_fn(pg, mesh, cfg, lanes, trace=True)
            out = tfn(arrays, np.asarray(
                (roots[:lanes] + [-1] * lanes)[:lanes], np.int32))
            trace_doc = export_trace(flightrec.TraversalTrace.from_buffer(
                np.asarray(out[-1]), algo="bc", sync=cfg.sync, p=pg.p,
                fanout=cfg.fanout, n_words=n_flat,
                capacity=cfg.resolved_capacity(n_flat),
                density_threshold=cfg.density_threshold,
            ))
        if args.stats_json:
            write_stats_json(
                args.stats_json, algo="bc", graph=graph_doc,
                devices=args.devices, config=config_doc,
                timing_ms={"mean": dt * 1e3 / max(n_roots, 1),
                           "total": dt * 1e3},
                engine_stats=eng.stats,
                **({"trace": trace_doc} if trace_doc else {}),
            )
        return 0

    if args.algo in ("pagerank", "cc", "tri", "kcore"):
        from repro import programs
        from repro.analytics.engine import BFSQueryEngine, EngineStats

        if args.sync not in programs.SYNCS:
            ap.error(f"--algo {args.algo} supports --sync {programs.SYNCS}, "
                     f"got {args.sync!r}")
        prog = programs.by_name(args.algo)
        pcfg = programs.ProgramConfig(
            axes=("data",), fanout=args.fanout, sync=args.sync,
            sparse_capacity=args.sparse_capacity,
            density_threshold=args.density_threshold,
        )
        eng = BFSQueryEngine(pg, mesh, cfg)
        eng.run_program(args.algo, pcfg)  # warmup / compile
        eng.stats = EngineStats()
        reps = 3  # programs are root-free: a few reps average the timing
        times = []
        for _ in range(reps):
            t0 = time.time()
            res, iters, work = eng.run_program(args.algo, pcfg)
            times.append(time.time() - t0)
        t = np.array(times)
        print(
            f"{args.algo} {args.sync} fanout={args.fanout} "
            f"devices={args.devices}: {iters} rounds in {t.mean()*1e3:.1f}ms"
            f"  GEdge/s {work/t.mean()/1e9:.4f} (host-simulated devices)"
        )
        if args.algo == "pagerank":
            top = np.argsort(res)[::-1][:5]
            print("top-5 ranked vertices:",
                  ", ".join(f"{v}={res[v]:.2e}" for v in top))
        elif args.algo == "cc":
            print(f"components: {np.unique(res[:g.n_real]).size}")
        elif args.algo == "tri":
            print(f"total triangles: {programs.total_triangles(res):,}")
        else:
            print(f"max core number: {int(res.max())} "
                  f"(degeneracy of the symmetrized graph)")
        trace_doc = None
        if args.trace:
            from repro.core import flightrec

            n_words = programs.program_msg_words(pg, prog)
            arrays = bfs.place_arrays(pg, mesh, pcfg.axes)
            tfn = programs.build_program_fn(pg, mesh, prog, pcfg, trace=True)
            out = tfn(arrays, prog.default_arg(pg))
            trace_doc = export_trace(flightrec.TraversalTrace.from_buffer(
                np.asarray(out[-1]), algo=args.algo, sync=pcfg.sync, p=pg.p,
                fanout=pcfg.fanout, n_words=n_words,
                capacity=pcfg.resolved_capacity(n_words),
                density_threshold=pcfg.density_threshold,
            ))
        if args.stats_json:
            write_stats_json(
                args.stats_json, algo=args.algo, graph=graph_doc,
                devices=args.devices, config=config_doc,
                timing_ms={"mean": float(t.mean() * 1e3),
                           "total": float(t.sum() * 1e3)},
                engine_stats=eng.stats,
                **({"trace": trace_doc} if trace_doc else {}),
            )
        return 0

    if args.num_sources > 1:
        from repro.analytics.engine import BFSQueryEngine, EngineStats

        eng = BFSQueryEngine(pg, mesh, cfg, lanes=args.num_sources)
        eng.query(roots[: args.num_sources])  # warmup / compile
        eng.stats = EngineStats()
        t0 = time.time()
        eng.query(np.asarray(roots, np.int32))
        dt = time.time() - t0
        print(
            f"MS-BFS {args.sync} fanout={args.fanout} mode={args.mode} "
            f"devices={args.devices} lanes={args.num_sources}: "
            f"{n_roots} searches in {dt*1e3:.1f}ms over {eng.stats.waves} "
            f"waves  ({n_roots/dt:.1f} searches/s, aggregate GTEP/s "
            f"{eng.stats.scanned_edges/dt/1e9:.4f}; host-simulated devices)"
        )
        trace_doc = None
        if args.trace:
            from repro.analytics import msbfs as ms
            from repro.core import flightrec

            n_flat = ms.wave_rows(pg) * ms.lane_words(args.num_sources)
            arrays = bfs.place_arrays(pg, mesh, cfg.axes)
            tfn = ms.build_msbfs_fn(pg, mesh, cfg, args.num_sources,
                                    trace=True)
            wave = (roots[: args.num_sources]
                    + [-1] * args.num_sources)[: args.num_sources]
            _, _, _, buf = tfn(arrays, np.asarray(wave, np.int32))
            trace_doc = export_trace(flightrec.TraversalTrace.from_buffer(
                np.asarray(buf), algo="msbfs", sync=cfg.sync, p=pg.p,
                fanout=cfg.fanout, n_words=n_flat,
                capacity=cfg.resolved_capacity(n_flat),
                density_threshold=cfg.density_threshold,
            ))
        if args.profile:
            emit_profile(eng.profile(roots[0]))
        if args.stats_json:
            write_stats_json(
                args.stats_json, algo="bfs", graph=graph_doc,
                devices=args.devices, config=config_doc,
                timing_ms={"mean": dt * 1e3 / max(n_roots, 1),
                           "total": dt * 1e3},
                engine_stats=eng.stats,
                **({"trace": trace_doc} if trace_doc else {}),
            )
        return 0

    layout = None
    if cfg.use_pallas:
        from repro.kernels import blocks

        layout = blocks.build_bfs_layout(pg)
    arrays = bfs.place_arrays(pg, mesh, cfg.axes, layout)
    fn = bfs.build_bfs_fn(pg, mesh, cfg, layout)
    # warmup / compile
    d, lvl, scanned = fn(arrays, np.int32(roots[0]))
    jax.block_until_ready(d)

    times, gteps = [], []
    scanned_total, max_lvl = 0.0, 0
    for r in roots:
        t0 = time.time()
        d, lvl, scanned = fn(arrays, np.int32(r))
        jax.block_until_ready(d)
        dt = time.time() - t0
        times.append(dt)
        gteps.append(float(scanned[0]) / dt / 1e9)
        scanned_total += float(scanned[0])
        max_lvl = max(max_lvl, int(np.max(lvl)))
    # paper protocol: drop fastest/slowest quartile
    order = np.argsort(times)
    keep = order[len(order) // 4 : -len(order) // 4] if len(order) >= 8 else order
    t = np.array(times)[keep]
    g_ = np.array(gteps)[keep]
    print(
        f"BFS {args.sync} fanout={args.fanout} mode={args.mode} "
        f"devices={args.devices}: time {t.mean()*1e3:.1f}ms  "
        f"GTEP/s {g_.mean():.4f} (host-simulated devices; "
        f"see EXPERIMENTS.md for the measurement caveat)"
    )
    trace_doc = None
    if args.trace:
        from repro.core import flightrec

        # host-timed segmented execution: per-level wall clock next to the
        # in-program sync/branch/byte attribution (DESIGN.md §18)
        _, tr = flightrec.timed_bfs_levels(
            pg, mesh, cfg, roots[0], arrays=arrays
        )
        trace_doc = export_trace(tr)
    if args.profile:
        from repro.core import profiler

        emit_profile({"program": profiler.profile_bfs(
            pg, mesh, cfg, roots[0], arrays=arrays
        ), "cache": []})
    if args.stats_json:
        from repro.analytics.engine import EngineStats

        write_stats_json(
            args.stats_json, algo="bfs", graph=graph_doc,
            devices=args.devices, config=config_doc,
            timing_ms={"mean": float(t.mean() * 1e3),
                       "total": float(np.sum(times) * 1e3)},
            engine_stats=EngineStats(
                queries=len(roots), waves=len(roots),
                scanned_edges=scanned_total, max_levels=max_lvl,
            ),
            **({"trace": trace_doc} if trace_doc else {}),
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
