"""Distributed ButterFly BFS launcher (the paper's workload, end to end).

``python -m repro.launch.bfs_run --scale 16 --devices 8 --fanout 4``

Generates a Kronecker graph, 1D-partitions it over simulated devices,
runs BFS from random roots with the paper's benchmarking protocol
(100 roots, trim fastest/slowest 25%) and reports GTEP/s.

``--num-sources B`` (B > 1) switches to the bit-parallel multi-source
engine (DESIGN.md §13): the ``--roots`` queries are packed into B-lane
waves and the report adds aggregate searches/s.

``--algo sssp`` runs weighted single-source shortest paths (butterfly
min-reduce; requires ``--max-weight``, defaulted when omitted) and
``--algo bc`` runs Brandes betweenness centrality waves over the root
queries (DESIGN.md §14).
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", default="kronecker",
                    choices=["kronecker", "urand", "torus"])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--fanout", type=int, default=4)
    ap.add_argument("--sync", default="butterfly",
                    choices=["butterfly", "sparse", "adaptive", "rabenseifner",
                             "all_to_all", "xla"])
    ap.add_argument("--sparse-capacity", type=int, default=0,
                    help="first-round (word,idx)-pair capacity of the sparse "
                         "sync; 0 = auto (n_words//64)")
    ap.add_argument("--density-threshold", type=float, default=0.02,
                    help="adaptive sync: go sparse while max popcount <= "
                         "threshold * bitmap bits")
    ap.add_argument("--mode", default="top_down",
                    choices=["top_down", "bottom_up", "direction_optimizing"])
    ap.add_argument("--algo", default="bfs", choices=["bfs", "sssp", "bc"],
                    help="traversal workload: unweighted BFS, weighted "
                         "shortest paths, or betweenness centrality")
    ap.add_argument("--max-weight", type=int, default=0,
                    help="uint32 edge weights in [1, max-weight]; 0 = "
                         "unweighted (sssp defaults to 64)")
    ap.add_argument("--delta", type=int, default=0,
                    help="sssp bucket width (delta-stepping-style); 0 = "
                         "level-synchronous relaxation")
    ap.add_argument("--roots", type=int, default=16,
                    help="number of root queries to run")
    ap.add_argument("--num-sources", type=int, default=1,
                    help="BFS lanes per wave: 1 = classic single-source; "
                         ">1 packs the root queries into bit-parallel "
                         "multi-source waves (analytics.msbfs)")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )
    import time

    import jax
    import numpy as np

    from repro.core import bfs
    from repro.graph import csr, generators, partition

    max_weight = args.max_weight
    if args.algo == "sssp" and not max_weight:
        max_weight = 64
    if args.graph == "kronecker":
        g = generators.kronecker(args.scale, args.edge_factor, seed=args.seed,
                                 max_weight=max_weight)
    elif args.graph == "urand":
        g = generators.uniform_random(
            1 << args.scale, (1 << args.scale) * args.edge_factor,
            seed=args.seed, max_weight=max_weight,
        )
    else:
        g = generators.torus_2d(1 << (args.scale // 2), max_weight=max_weight,
                                seed=args.seed)
    print(f"graph: n={g.n:,} m={g.n_edges:,} (directed, symmetrized"
          f"{', weighted' if g.weighted else ''})")
    pg = partition.partition_1d(g, args.devices)
    mesh = jax.make_mesh((args.devices,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = bfs.BFSConfig(
        axes=("data",), fanout=args.fanout, sync=args.sync, mode=args.mode,
        use_pallas=args.pallas, sparse_capacity=args.sparse_capacity,
        density_threshold=args.density_threshold,
    )
    rng = np.random.default_rng(args.seed)
    roots = [csr.largest_component_root(g, rng) for _ in range(args.roots)]

    if args.algo == "sssp":
        from repro.traversal import sssp as sssp_mod

        if args.sync not in sssp_mod.SYNCS:
            ap.error(f"--algo sssp supports --sync {sssp_mod.SYNCS}, "
                     f"got {args.sync!r}")
        scfg = sssp_mod.SSSPConfig(
            axes=("data",), fanout=args.fanout, sync=args.sync,
            delta=args.delta, sparse_capacity=args.sparse_capacity,
            density_threshold=args.density_threshold,
        )
        arrays = bfs.place_arrays(pg, mesh, scfg.axes)
        fn = sssp_mod.build_sssp_fn(pg, mesh, scfg)
        d, it, relaxed = fn(arrays, np.int32(roots[0]))  # warmup / compile
        jax.block_until_ready(d)
        times, rates = [], []
        for r in roots:
            t0 = time.time()
            d, it, relaxed = fn(arrays, np.int32(r))
            jax.block_until_ready(d)
            dt = time.time() - t0
            times.append(dt)
            rates.append(float(relaxed[0]) / dt / 1e9)
        t = np.array(times)
        print(
            f"SSSP {scfg.sync} fanout={args.fanout} delta={args.delta} "
            f"devices={args.devices}: time {t.mean()*1e3:.1f}ms  "
            f"GRelax/s {np.mean(rates):.4f} (host-simulated devices)"
        )
        return 0

    if args.algo == "bc":
        from repro.analytics.engine import BFSQueryEngine

        lanes = max(args.num_sources, 1)
        eng = BFSQueryEngine(pg, mesh, cfg, lanes=lanes)
        eng.betweenness(roots[:lanes])  # warmup / compile
        t0 = time.time()
        bc_scores = eng.betweenness(np.asarray(roots, np.int32))
        dt = time.time() - t0
        top = np.argsort(bc_scores)[::-1][:5]
        print(
            f"BC {args.sync} fanout={args.fanout} devices={args.devices} "
            f"lanes={lanes}: {args.roots} sources in {dt*1e3:.1f}ms "
            f"({args.roots/dt:.1f} sources/s; host-simulated devices)"
        )
        print("top-5 central vertices:",
              ", ".join(f"{v}={bc_scores[v]:.1f}" for v in top))
        return 0

    if args.num_sources > 1:
        from repro.analytics.engine import BFSQueryEngine, EngineStats

        eng = BFSQueryEngine(pg, mesh, cfg, lanes=args.num_sources)
        eng.query(roots[: args.num_sources])  # warmup / compile
        eng.stats = EngineStats()
        t0 = time.time()
        eng.query(np.asarray(roots, np.int32))
        dt = time.time() - t0
        print(
            f"MS-BFS {args.sync} fanout={args.fanout} mode={args.mode} "
            f"devices={args.devices} lanes={args.num_sources}: "
            f"{args.roots} searches in {dt*1e3:.1f}ms over {eng.stats.waves} "
            f"waves  ({args.roots/dt:.1f} searches/s, aggregate GTEP/s "
            f"{eng.stats.scanned_edges/dt/1e9:.4f}; host-simulated devices)"
        )
        return 0

    layout = None
    if cfg.use_pallas:
        from repro.kernels import blocks

        layout = blocks.build_bfs_layout(pg)
    arrays = bfs.place_arrays(pg, mesh, cfg.axes, layout)
    fn = bfs.build_bfs_fn(pg, mesh, cfg, layout)
    # warmup / compile
    d, lvl, scanned = fn(arrays, np.int32(roots[0]))
    jax.block_until_ready(d)

    times, gteps = [], []
    for r in roots:
        t0 = time.time()
        d, lvl, scanned = fn(arrays, np.int32(r))
        jax.block_until_ready(d)
        dt = time.time() - t0
        times.append(dt)
        gteps.append(float(scanned[0]) / dt / 1e9)
    # paper protocol: drop fastest/slowest quartile
    order = np.argsort(times)
    keep = order[len(order) // 4 : -len(order) // 4] if len(order) >= 8 else order
    t = np.array(times)[keep]
    g_ = np.array(gteps)[keep]
    print(
        f"BFS {args.sync} fanout={args.fanout} mode={args.mode} "
        f"devices={args.devices}: time {t.mean()*1e3:.1f}ms  "
        f"GTEP/s {g_.mean():.4f} (host-simulated devices; "
        f"see EXPERIMENTS.md for the measurement caveat)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
