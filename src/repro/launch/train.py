"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant loop on whatever devices exist (CPU smoke / a real
pod).  ``--smoke`` swaps in the reduced same-family config so any assigned
architecture trains a few steps on this container.
"""

from __future__ import annotations

import argparse


def main(argv=None) -> int:
    import jax

    from repro import configs
    from repro.dist.sharding import rules_for_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.train.loop import LoopConfig, train

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-sync", default="xla",
                    choices=["xla", "butterfly", "rabenseifner", "all_to_all"])
    ap.add_argument("--fanout", type=int, default=2)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.reduced(cfg)
    mesh = make_host_mesh()
    rules = rules_for_mesh(mesh, cfg.fsdp and args.grad_sync == "xla")
    out = train(
        cfg, args.batch, args.seq,
        LoopConfig(
            n_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at,
            microbatches=args.microbatches, grad_sync=args.grad_sync,
            fanout=args.fanout,
            lr_kw={"warmup": 10, "total": args.steps},
        ),
        mesh=mesh, rules=rules,
    )
    losses = out["losses"]
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
