"""Analytic roofline corrections for scans that survive analysis mode.

In analysis mode (``cfg.scan_unroll``) every layer scan is fully unrolled
and every inner scan with <= 8 trips unrolls too, so XLA ``cost_analysis``
counts them exactly.  The ONE remaining undercount is the query-chunk scan
inside prefill attention when ``n_chunks > 8`` (prefill_32k: 32 trips):
cost_analysis counts its body once, i.e. 1/n of the true score flops.

This module adds back the missing ``(n-1)`` bodies with the exact matmul
formula (scores + PV: ``4·B·Hq·C·Lk·hd`` flops per chunk; KV bytes re-read
per chunk).  The chunking plan is imported from ``layers.attn_chunking`` so
the correction can never drift from the model code.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import attn_chunking


def _layer_correction(cfg: ModelConfig, b: int, l: int, is_global: bool):
    q_chunk, n, unroll = attn_chunking(cfg, l, causal=True)
    if n == 1 or unroll == n:  # exact in HLO
        return 0.0, 0.0
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    lk = l if is_global else (cfg.local_window + q_chunk)
    flops_per_chunk = 4.0 * b * hq * q_chunk * lk * hd
    kv_bytes_per_chunk = 2.0 * b * lk * hk * hd * 2  # bf16 k + v
    return (n - 1) * flops_per_chunk, (n - 1) * kv_bytes_per_chunk


def prefill_corrections(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global (all-chips) flops/bytes to ADD to the HLO-derived terms."""
    if shape.kind != "prefill":
        return {"flops": 0.0, "bytes": 0.0}
    b, l = shape.global_batch, shape.seq_len
    flops = byts = 0.0
    if cfg.family == "audio":
        # decoder self-attention layers (encoder is single-chunk: exact)
        f1, b1 = _layer_correction(cfg, b, l, is_global=True)
        return {"flops": cfg.n_layers * f1, "bytes": cfg.n_layers * b1}
    for i in range(cfg.n_layers):
        if not cfg.is_attn_layer(i):
            continue
        f1, b1 = _layer_correction(cfg, b, l, cfg.is_global_attn_layer(i))
        flops += f1
        byts += b1
    return {"flops": flops, "bytes": byts}
