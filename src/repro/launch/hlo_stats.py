"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` gives per-device HLO_FLOPs / bytes-accessed;
collective bytes are NOT in cost_analysis, so we parse the optimized HLO
(``compiled.as_text()``) and sum operand sizes of every collective op
(brief: ROOFLINE ANALYSIS).  We additionally estimate *wire* bytes per
device from the replica-group size (ring all-gather moves (P-1)/P of the
full buffer per device, etc.) — both are recorded.

Hardware constants: TPU v5e per chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the brief).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (we assume one busy link per phase)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(
    # operands may carry an inline `f32[..]{..}` type prefix (XLA version
    # dependent) — skip it and capture the operand names.
    r"=\s*[a-z0-9]+\[([0-9,]*)\][^ ]*\s+dot\("
    r"(?:[a-z0-9]+\[[0-9,]*\]\{[^}]*\}\s+)?(%[\w.\-]+), "
    r"(?:[a-z0-9]+\[[0-9,]*\]\{[^}]*\}\s+)?(%[\w.\-]+)\)"
)
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def dot_flops(hlo_text: str) -> float:
    """MXU flops per device: 2 · |result| · |contraction| summed over every
    ``dot`` in the optimized HLO.

    Why not ``cost_analysis()['flops']``: on the CPU backend XLA inserts
    bf16→f32 converts (no native bf16 dot) that HloCostAnalysis counts as
    flops — for decode steps those cache-sized converts dominate the count
    by 60× (measured; DESIGN.md §10).  TPU has native bf16 MXU dots, so the
    dot-only number is the hardware-meaningful compute term.  Operand
    shapes come from a name→shape symbol table over the module text.
    """
    shapes = {}
    total = 0.0
    for line in hlo_text.splitlines():
        md = _DEF_RE.match(line)
        if md:
            dims = [int(x) for x in md.group(3).split(",") if x]
            shapes[md.group(1)] = dims
        m = _DOT_RE.search(line)
        if not m:
            continue
        result = [int(x) for x in m.group(1).split(",") if x]
        lhs = shapes.get(m.group(2))
        mc = _LHS_C_RE.search(line)
        if lhs is None or mc is None:
            continue
        cdims = [int(x) for x in mc.group(1).split(",") if x]
        contraction = _prod([lhs[i] for i in cdims if i < len(lhs)])
        total += 2.0 * _prod(result) * contraction
    return total


_PAIRS_RE = re.compile(r"source_target_pairs=\{\{([0-9]+),")


def _empty_stats() -> Dict[str, Dict[str, float]]:
    return {
        k: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
        for k in _COLLECTIVES
    }


def _accumulate_lines(lines) -> Dict[str, Dict[str, float]]:
    stats = _empty_stats()
    for line in lines:
        parsed = _collective_line_stats(line)
        if parsed is None:
            continue
        kind, obytes, wire = parsed
        stats[kind]["count"] += 1
        stats[kind]["operand_bytes"] += obytes
        stats[kind]["wire_bytes"] += wire
    return stats


def _collective_line_stats(line: str):
    """Parse one HLO line; returns ``(kind, operand_bytes, wire_bytes)`` for
    collective ops, else None.  Shared by the whole-module and
    per-computation accounting."""
    m = _OP_RE.search(line)
    if not m:
        return None
    kind = m.group(1)
    if f"{kind}-done(" in line:  # async pair: count only the -start
        return None
    rm = _SHAPE_RE.search(line)
    if not rm:
        return None
    rbytes = _shape_bytes(rm.group(1), rm.group(2))
    gm = _GROUPS_RE.search(line)
    gsize = len(gm.group(1).split(",")) if gm else 1
    gsize = max(gsize, 1)
    if kind == "all-gather":
        obytes = rbytes / gsize
        full = float(rbytes)
    elif kind == "reduce-scatter":
        obytes = rbytes * gsize
        full = float(obytes)
    else:
        obytes = float(rbytes)
        full = float(rbytes)
    if kind == "all-reduce":
        wire = 2.0 * full * (gsize - 1) / gsize
    elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
        wire = full * (gsize - 1) / gsize
    else:  # collective-permute: operand goes out once
        wire = float(obytes)
    return kind, float(obytes), wire


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective-op-kind: count, operand bytes, wire-bytes estimate.

    Operands appear as bare names in optimized HLO, so operand sizes are
    derived from the RESULT shape + replica-group size using each op's
    semantics (all-gather result = operand × P, reduce-scatter result =
    operand / P, all-reduce / permute / all-to-all result = operand).
    Wire bytes use the standard ring/bidirectional estimates per device:
    all-reduce 2·N·(P-1)/P, all-gather & reduce-scatter N·(P-1)/P of the
    FULL buffer, all-to-all N·(P-1)/P, permute N.
    """
    return _accumulate_lines(hlo_text.splitlines())


# ---------------------------------------------------------------------------
# Branch-attributed collective accounting (for adaptive/conditional programs)
# ---------------------------------------------------------------------------

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _segment_computations(hlo_text: str):
    """Split HLO module text into named computations.

    Returns ``(lines_by_comp, callees_by_comp)`` where callees include
    computations referenced via ``calls=`` / ``body=`` / ``condition=`` /
    ``to_apply=`` / ``branch_computations=`` (for transitive aggregation).
    """
    lines: Dict[str, list] = {}
    callees: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        hm = _COMP_HEADER_RE.match(line)
        if hm:
            current = hm.group(1)
            lines.setdefault(current, [])
            callees.setdefault(current, [])
            continue
        if current is None:
            continue
        if line.startswith("}"):
            current = None
            continue
        lines[current].append(line)
        callees[current].extend(_CALLS_RE.findall(line))
        bm = _BRANCHES_RE.search(line)
        if bm:
            callees[current].extend(
                n.strip().lstrip("%") for n in bm.group(1).split(",")
            )
    return lines, callees


def computation_collective_stats(
    hlo_text: str, *, transitive: bool = True
) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Per-HLO-computation collective stats (same shape as
    :func:`collective_stats`).  With ``transitive=True`` each computation
    also absorbs the stats of everything it calls — so a ``lax.cond``
    branch's total includes collectives hidden in fusions/loops it invokes.
    """
    lines, callees = _segment_computations(hlo_text)
    direct = {name: _accumulate_lines(ls) for name, ls in lines.items()}
    if not transitive:
        return direct

    memo: Dict[str, Dict] = {}

    def total(name: str, seen) -> Dict:
        if name in memo:
            return memo[name]
        if name in seen or name not in direct:
            return _empty_stats()
        seen = seen | {name}
        agg = {k: dict(v) for k, v in direct[name].items()}
        for callee in callees.get(name, []):
            sub = total(callee, seen)
            for k in _COLLECTIVES:
                for field in ("count", "operand_bytes", "wire_bytes"):
                    agg[k][field] += sub[k][field]
        memo[name] = agg
        return agg

    return {name: total(name, frozenset()) for name in direct}


def conditional_branch_stats(hlo_text: str):
    """Collective stats per ``lax.cond`` branch of the compiled program.

    ``collective_stats`` sums BOTH branches of a conditional — static HLO
    has no notion of which branch runs — which misreports adaptive
    collectives.  This walks every ``conditional(...)`` op and returns, in
    program order, a list of per-branch stats lists: one entry per
    conditional, each a list (branch order preserved: branch 0 = the
    ``lax.cond`` False path) of ``(computation_name, stats)`` tuples.
    """
    comp_stats = computation_collective_stats(hlo_text)
    out = []
    for line in hlo_text.splitlines():
        if " conditional(" not in line and "conditional-start" not in line:
            continue
        bm = _BRANCHES_RE.search(line)
        if not bm:
            continue
        names = [n.strip().lstrip("%") for n in bm.group(1).split(",")]
        for n in names:
            if n not in comp_stats:
                raise ValueError(
                    f"conditional references computation {n!r} that the "
                    "parser did not segment — HLO header format change?"
                )
        out.append([(n, comp_stats[n]) for n in names])
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_operand_bytes: float
    collective_wire_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step-time estimate: max of the three terms (they can
        overlap on TPU: MXU vs HBM DMA vs ICI)."""
        return max(self.t_compute, self.t_memory, self.t_collective)


def roofline_from(compiled, hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cs = collective_stats(text)
    op_b = sum(v["operand_bytes"] for v in cs.values())
    wire_b = sum(v["wire_bytes"] for v in cs.values())
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byt,
        collective_operand_bytes=op_b,
        collective_wire_bytes=wire_b,
        t_compute=flops / PEAK_FLOPS,
        t_memory=byt / HBM_BW,
        t_collective=wire_b / ICI_BW,
    )


def memory_stats(compiled) -> Dict[str, float]:
    ms = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[k] = float(getattr(ms, k, 0) or 0)
    out["peak_bytes_per_device"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out
