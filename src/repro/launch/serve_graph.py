"""Graph-query serving CLI (DESIGN.md §15).

``python -m repro.launch.serve_graph --scale 12 --devices 8 --duration 5``

Builds a graph, 1D-partitions it over simulated devices, starts a
:class:`~repro.service.GraphQueryService`, and drives it with a built-in
open-loop load (mixed ``bfs``/``closeness`` root queries at ``--qps``,
per-request ``--deadline-ms``); on exit it prints — and with
``--stats-json`` persists — the full telemetry snapshot (p50/p95/p99
latency, QPS, wave occupancy, cache hit rate) alongside the engine stats,
using the ``bfs_run`` stats schema extended with a ``telemetry`` block.

``--swap-after N`` swaps in a fresh graph (new seed) after ``N`` requests
to exercise the epoch-bump invalidation path under live traffic.

``--mutate-rate R`` injects ``R`` random edge-mutation batches per second
into the open-loop driver (``--mutate-edges`` inserts and
``--mutate-delete-frac`` of that many deletions each) through
``GraphQueryService.apply_updates`` — the §16 streaming path: the
partition is patched in place, cached rows are proven-unchanged /
repaired / dropped per batch, and the report adds the
partial-invalidation hit-rate (surviving-row fraction) next to the
existing telemetry.  ``--record-updates PATH`` persists the injected
batches as a JSONL stream replayable by ``bfs_run --updates``.

``--replicas N`` serves through N independent engine replicas behind the
§17 version-aware router: mutations fan out through the replication log
with read-your-writes ``min_seq``, failures fail over, and the stats gain
a ``faults`` telemetry block (injected faults, retries, hedges,
failovers, recoveries, shed, stale serves — zeroed on the single-service
path so the ``--stats-json`` schema is uniform).  ``--chaos SPEC`` arms
the deterministic fault injector (``--chaos-seed`` fixes the victim
draws), e.g. ``--chaos 'kill-one@op=20;corrupt-batch@batch=2'``.

The §21 ops plane rides on top: ``--events PATH`` streams the structured
event log (``ops_events/v1`` JSONL, validate with ``python -m
repro.core.events``); ``--slo-config PATH`` loads declarative SLOs and
evaluates Google-SRE multi-window burn-rate alerts live, folding the
machine-readable verdict into ``--stats-json`` (schema
``serve_graph_stats/v2``) and, with ``--slo-verdict PATH``, its own JSON;
``--metrics-port`` additionally serves the live console
(``/debug/requests|replicas|cache|slo|events`` + ``/dashboard``);
``--dashboard-html PATH`` saves the self-contained dashboard page as a CI
artifact.
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--fanout", type=int, default=4)
    ap.add_argument("--sync", default="adaptive",
                    choices=["butterfly", "sparse", "adaptive", "rabenseifner",
                             "all_to_all", "xla"])
    ap.add_argument("--lanes", type=int, default=32,
                    help="wave width (bit-lanes per MS-BFS wave)")
    ap.add_argument("--qps", type=float, default=100.0,
                    help="offered open-loop arrival rate")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="seconds of offered load")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline; 0 = best-effort")
    ap.add_argument("--linger-ms", type=float, default=5.0,
                    help="max wave linger before a partial dispatch")
    ap.add_argument("--cache-capacity", type=int, default=1024)
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="admission-control queue bound")
    ap.add_argument("--algos", default="bfs,closeness",
                    help="comma list drawn per request: traversals "
                         "(bfs,closeness,sssp,bc) and/or §19 vertex "
                         "programs (pagerank,cc,tri,kcore — root-free; "
                         "each gets its own single-result wave class)")
    ap.add_argument("--hot-fraction", type=float, default=0.2,
                    help="fraction of requests hitting one hot root "
                         "(exercises dedup + the result cache)")
    ap.add_argument("--swap-after", type=int, default=0,
                    help="swap in a reseeded graph after N requests "
                         "(exercises epoch invalidation); 0 = never")
    ap.add_argument("--mutate-rate", type=float, default=0.0,
                    help="edge-mutation batches per second injected into "
                         "the load (0 = static graph)")
    ap.add_argument("--mutate-edges", type=int, default=16,
                    help="undirected edge inserts per mutation batch")
    ap.add_argument("--mutate-delete-frac", type=float, default=0.25,
                    help="deletions per batch as a fraction of "
                         "--mutate-edges")
    ap.add_argument("--record-updates", default=None, metavar="PATH",
                    help="persist injected mutation batches as a JSONL "
                         "stream (replay with `bfs_run --updates PATH`)")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="serve through N independent engine replicas "
                         "behind the §17 version-aware router")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault-injection spec, e.g. "
                         "'kill-one@op=20;corrupt-batch@batch=2' "
                         "(requires --replicas > 1 to stay available)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="seed for fault victim draws (default: --seed)")
    ap.add_argument("--router-timeout-s", type=float, default=30.0,
                    help="router per-request budget before the hedged "
                         "duplicate fires (replicated path only); lower it "
                         "with a stall chaos spec to see the hedge in a "
                         "short --trace run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the §20 metrics registry over HTTP while "
                         "the load runs: GET /metrics is Prometheus text "
                         "exposition, GET /healthz reports per-replica "
                         "health state and replication lag (0 = pick a "
                         "free port; printed at startup)")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append a JSONL snapshot of every registry series "
                         "at exit (machine-readable metrics artifact)")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump telemetry + engine stats as JSON "
                         "(serve_graph_stats/v2; adds an `slo` block when "
                         "--slo-config is active)")
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="stream the §21 structured event log as "
                         "ops_events/v1 JSONL (validate: python -m "
                         "repro.core.events PATH --schema "
                         "tests/event_schema.json)")
    ap.add_argument("--slo-config", default=None, metavar="PATH",
                    help="slo_config/v1 JSON: declarative SLOs evaluated "
                         "live with multi-window burn-rate alerting")
    ap.add_argument("--slo-verdict", default=None, metavar="PATH",
                    help="write the slo_verdict/v1 JSON at exit (assert "
                         "with python -m repro.core.slo)")
    ap.add_argument("--dashboard-html", default=None, metavar="PATH",
                    help="save the self-contained /dashboard page (no "
                         "server needed; CI uploads it as an artifact)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export a §18 cross-stack request trace as "
                         "Perfetto/Chrome trace_event JSON (load at "
                         "ui.perfetto.dev); FILE.jsonl gets the raw "
                         "event stream")
    args = ap.parse_args(argv)
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.swap_after and args.replicas > 1:
        ap.error("--swap-after is a single-service path; use mutations "
                 "(--mutate-rate) with --replicas")

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )
    import json
    import time

    import jax
    import numpy as np

    from repro.core import bfs
    from repro.graph import csr, generators, partition
    from repro.service import (
        AdmissionError,
        FaultInjector,
        GraphQueryService,
        Replica,
        ReplicaRouter,
        RouterTelemetry,
    )

    def build(seed):
        g = generators.kronecker(args.scale, args.edge_factor, seed=seed)
        return g, partition.partition_1d(g, args.devices)

    from repro.core import events as events_mod
    from repro.core.tracing import NULL_TRACER, Tracer

    tracer = Tracer() if args.trace else NULL_TRACER
    event_log = events_mod.default_event_log()
    if args.events:
        event_log.attach_sink(args.events)

    g, pg = build(args.seed)
    print(f"graph: n={g.n_real:,} m={g.n_edges:,}")
    mesh = jax.make_mesh((args.devices,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = bfs.BFSConfig(axes=("data",), fanout=args.fanout, sync=args.sync)
    algos = [a.strip() for a in args.algos.split(",") if a.strip()]
    from repro.service.queue import ALGOS as _ALGOS

    bad = [a for a in algos if a not in _ALGOS]
    if bad:
        ap.error(f"--algos {bad} not servable; expected from {_ALGOS}")

    service_kw = dict(
        cache_capacity=args.cache_capacity, max_pending=args.max_pending,
        max_linger_s=args.linger_ms / 1e3,
        default_deadline_s=(args.deadline_ms / 1e3) or None,
    )
    rng = np.random.default_rng(args.seed)
    hot = csr.largest_component_root(g, rng)
    replicated = args.replicas > 1 or args.chaos is not None
    router = injector = None
    if replicated:
        replicas = [
            Replica(i, g, args.devices, cfg, mesh=mesh, lanes=args.lanes,
                    n_real=g.n_real, service_kw=dict(service_kw),
                    tracer=tracer if args.trace else None)
            for i in range(args.replicas)
        ]
        for r in replicas:  # warmup / compile before measuring
            r.submit("bfs", hot).result(600.0)
            r.svc.reset_telemetry()
        tracer.clear()  # warmup spans must not pollute the exported trace
        injector = FaultInjector.from_spec(
            args.chaos,
            args.seed if args.chaos_seed is None else args.chaos_seed,
            args.replicas,
        )
        router = ReplicaRouter(replicas, injector=injector,
                               timeout_s=args.router_timeout_s,
                               tracer=tracer if args.trace else None)
        svc = replicas[0].svc  # overlay source for sampled batches
        if args.chaos:
            print(f"chaos: {args.chaos} -> "
                  f"{json.dumps(injector.schedule_json())}")
    else:
        svc = GraphQueryService(
            pg, mesh, cfg, lanes=args.lanes, n_real=g.n_real,
            tracer=tracer if args.trace else None, **service_kw
        )
        svc.query("bfs", hot)  # warmup / compile
        svc.reset_telemetry()  # compiles must not pollute measured latency
        tracer.clear()  # same for the exported trace
    print(f"serving: replicas={args.replicas} lanes={args.lanes} "
          f"sync={args.sync} linger={args.linger_ms}ms qps={args.qps} "
          f"deadline={args.deadline_ms or 'none'}ms")

    slo_mgr = None
    if args.slo_config:
        from repro.core import metrics as metrics_mod
        from repro.core import slo as slo_mod

        reg = metrics_mod.default_registry()
        slo_config = slo_mod.load_config(args.slo_config)

        def source_for(obj):
            if obj.type == "latency":
                if replicated:
                    return slo_mod.latency_threshold_source(
                        reg, "router_latency_ms", obj.threshold_ms)
                return slo_mod.latency_threshold_source(
                    reg, "service_latency_ms", obj.threshold_ms,
                    {"stage": "total"})
            if obj.type == "staleness":
                if replicated:
                    return slo_mod.counter_events_source(
                        reg, "router_events_total",
                        good=("completed",), bad=("stale_serves",))
                return lambda: (0.0, 0.0)  # no degraded path to go stale
            # availability = served cleanly: a retry/hedge/stale fallback
            # burns budget even when the client future still succeeds
            if replicated:
                return slo_mod.counter_events_source(
                    reg, "router_events_total",
                    good=("completed",),
                    bad=("failed", "retries", "hedges", "stale_serves"))
            return slo_mod.counter_events_source(
                reg, "service_events_total",
                good=("completed",),
                bad=("failed", "expired", "deadline_misses"))

        def exemplar_for(obj):
            if obj.type == "latency":
                return slo_mod.histogram_exemplar(
                    reg, "router_latency_ms" if replicated
                    else "service_latency_ms")
            # chaos-first: when a fault was injected, the exemplar is the
            # request the fault hit (its trace holds kill + hedge); retry
            # events cover organic degradation without chaos
            return slo_mod.event_log_exemplar(
                event_log, kinds=("chaos", "retry"))

        slo_mgr = slo_mod.build_from_config(
            slo_config, source_for, exemplar_for, events=event_log)
        print(f"slo: {len(slo_mgr.trackers)} objectives, "
              f"time_scale={slo_config.get('time_scale', 1.0)} "
              f"({args.slo_config})")

    metrics_server = None
    if args.metrics_port is not None:
        from repro.core import metrics as metrics_mod

        def health_fn():
            if replicated:
                head = router.latest_seq
                reps = [
                    {"replica": r.id, "state": r.state,
                     "applied_seq": int(r.applied_seq),
                     "lag": max(0, head - int(r.applied_seq))}
                    for r in router.replicas
                ]
                serving = sum(1 for r in reps if r["state"] != "DEAD")
                return {"status": "ok" if serving else "unavailable",
                        "head_seq": int(head), "replicas": reps}
            return {"status": "ok", "replicas": [
                {"replica": 0, "state": "HEALTHY", "applied_seq": 0,
                 "lag": 0}]}

        metrics_server = metrics_mod.MetricsServer(
            metrics_mod.default_registry(), port=args.metrics_port,
            health_fn=health_fn,
        )
        metrics_server.start()
        print(f"metrics: {metrics_server.url}/metrics  "
              f"{metrics_server.url}/healthz")

        from repro.service import console as console_mod

        if replicated:
            console_mod.install_console(
                metrics_server, events=event_log,
                debug_requests=router.debug_requests,
                replicas_fn=console_mod.replicas_feed(router),
                cache_fn=console_mod.cache_feed(router=router),
                slo=slo_mgr)
        else:
            console_mod.install_console(
                metrics_server, events=event_log,
                debug_requests=svc.debug_requests,
                replicas_fn=console_mod.single_service_replicas_feed(svc),
                cache_fn=console_mod.cache_feed(svc=svc),
                slo=slo_mgr)
        print(f"console: {metrics_server.url}/dashboard")

    n = max(int(args.qps * args.duration), 1)
    futs = []
    rejected = 0
    batches = []  # injected mutation batches (for --record-updates)
    n_mut = 0
    min_seq = router.latest_seq if replicated else 0
    slo_tick_s = 0.05  # burn-rate evaluation cadence while driving load
    next_slo = 0.0
    t0 = time.perf_counter()
    for i in range(n):
        if slo_mgr is not None:
            nowm = time.monotonic()
            if nowm >= next_slo:
                slo_mgr.tick(nowm)
                next_slo = nowm + slo_tick_s
        target = t0 + i / args.qps
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        if args.swap_after and i == args.swap_after:
            g, pg = build(args.seed + 1)
            epoch = svc.swap_graph(pg, n_real=g.n_real)
            print(f"  [swapped graph at request {i} -> epoch {epoch}]")
        if args.mutate_rate > 0:
            due = int((time.perf_counter() - t0) * args.mutate_rate)
            while n_mut < due:
                batch = svc.overlay.sample_batch(
                    rng, args.mutate_edges,
                    int(args.mutate_edges * args.mutate_delete_frac),
                )
                batches.append(batch)
                if replicated:  # replication log: fan out + read-your-writes
                    min_seq = router.apply_updates(batch)
                else:
                    svc.apply_updates(batch)
                n_mut += 1
        root = (hot if rng.random() < args.hot_fraction
                else int(rng.integers(0, g.n_real)))
        try:
            if replicated:
                futs.append(router.submit(algos[i % len(algos)], root,
                                          min_seq=min_seq))
            else:
                futs.append(svc.submit(algos[i % len(algos)], root))
        except AdmissionError:
            rejected += 1
    ok = err = stale = 0
    for f in futs:
        try:
            res = f.result(timeout=600)
            ok += 1
            if replicated and res.stale:
                stale += 1
        except Exception:
            err += 1
    elapsed = time.perf_counter() - t0
    slo_verdict = None
    if slo_mgr is not None:
        # final ticks AFTER every future resolved: the closing evaluation
        # sees all retries/hedges, and a PENDING alert gets its chance to
        # cross its hold-down into FIRING
        nowm = time.monotonic()
        slo_mgr.tick(nowm)
        slo_mgr.tick(nowm + slo_tick_s)
        slo_verdict = slo_mgr.verdict()
        fired = [a for a in slo_verdict["alerts"] if a["fired_count"] > 0]
        print(f"slo: ok={slo_verdict['ok']} "
              f"any_fired={slo_verdict['any_fired']}" + "".join(
                  f"  [{a['severity']}] {a['slo']}/{a['rule']} "
                  f"{a['state']} burn={a['burn_short']:.2f}x"
                  + (f" exemplar={a['exemplar']['trace_id']}"
                     if a.get("exemplar") else "")
                  for a in fired))

    if replicated:
        snap = router.snapshot()
    else:
        snap = svc.snapshot()
        # uniform --stats-json schema: the single-service path reports a
        # zeroed §17 faults block (nothing injected, nothing to fail over)
        snap["faults"] = RouterTelemetry().faults_block(injector)
    lat = snap["latency_ms"]
    if replicated:
        fb = snap["faults"]
        print(
            f"{ok}/{n} served in {elapsed:.2f}s ({ok/elapsed:.1f} QPS; "
            f"{rejected} rejected, {err} failed, {stale} stale)  "
            f"p50 {lat['p50']:.1f}ms  p95 {lat['p95']:.1f}ms  "
            f"p99 {lat['p99']:.1f}ms  replicas "
            f"{snap['n_serving']}/{args.replicas} serving "
            f"(host-simulated devices)"
        )
        print(
            f"faults: injected {sum(fb['injected'].values())}  "
            f"retries {fb['retries']}  hedges {fb['hedges']}  "
            f"failovers {fb['failovers']}  recoveries {fb['recoveries']}  "
            f"shed {fb['shed']}  stale serves {fb['stale_serves']}  "
            f"catch-up batches {fb['catch_up_batches']}"
        )
    else:
        print(
            f"{ok}/{n} served in {elapsed:.2f}s ({ok/elapsed:.1f} QPS; "
            f"{rejected} rejected, {err} failed/expired)  "
            f"p50 {lat['p50']:.1f}ms  p95 {lat['p95']:.1f}ms  "
            f"p99 {lat['p99']:.1f}ms  occupancy {snap['wave_occupancy']:.2f}  "
            f"cache hit-rate {snap['cache']['hit_rate']:.2f} "
            f"(host-simulated devices)"
        )
    if n_mut and not replicated:
        mut = snap["mutations"]
        print(
            f"mutations: {mut['batches']} batches "
            f"({mut['compactions']} compactions)  cached rows "
            f"{mut['rows_kept']} kept / {mut['rows_repaired']} repaired / "
            f"{mut['rows_dropped']} dropped  partial-invalidation "
            f"hit-rate {mut['survival_rate']:.2f}"
        )
    if args.record_updates and batches:
        from repro.dynamic import delta

        delta.write_update_stream(args.record_updates, batches)
        print(f"update stream ({len(batches)} batches) -> "
              f"{args.record_updates}")
    if args.stats_json:
        from repro.launch.bfs_run import write_stats_json

        # serve_graph_stats/v2 = v1 plus the optional `slo` block; every
        # v1 key keeps its name and shape, so v1 readers keep working
        write_stats_json(
            args.stats_json, algo="service",
            graph={"name": "kronecker", "scale": args.scale,
                   "edge_factor": args.edge_factor, "n": g.n,
                   "n_real": g.n_real, "n_edges": g.n_edges,
                   "weighted": bool(g.weighted)},
            devices=args.devices,
            config={"sync": args.sync, "mode": cfg.mode,
                    "fanout": args.fanout, "lanes": args.lanes,
                    "delta": 0, "max_weight": 0, "use_pallas": False,
                    "replicas": args.replicas,
                    "chaos": args.chaos or ""},
            timing_ms={"mean": lat["mean"], "total": elapsed * 1e3},
            engine_stats=svc.engine.stats,
            telemetry=snap,
            schema="serve_graph_stats/v2",
            slo=slo_verdict,
        )
        print(f"stats -> {args.stats_json}")
    if args.slo_verdict:
        if slo_verdict is None:
            print("slo-verdict requested without --slo-config; skipping",
                  file=sys.stderr)
        else:
            with open(args.slo_verdict, "w") as f:
                json.dump(slo_verdict, f, indent=1)
            print(f"slo verdict -> {args.slo_verdict}")
    if args.dashboard_html:
        from repro.service.console import DASHBOARD_HTML

        with open(args.dashboard_html, "w") as f:
            f.write(DASHBOARD_HTML)
        print(f"dashboard -> {args.dashboard_html}")
    if args.metrics_jsonl:
        from repro.core import metrics as metrics_mod

        n_series = metrics_mod.default_registry().write_jsonl(
            args.metrics_jsonl)
        print(f"metrics snapshot ({n_series} series) -> "
              f"{args.metrics_jsonl}")
    if metrics_server is not None:
        metrics_server.stop()
    if replicated:
        router.stop()
    else:
        svc.stop()
    if args.events:
        event_log.close_sink()
        print(f"event log ({len(event_log)} resident, "
              f"{event_log.snapshot()['emitted']} emitted) -> {args.events}")
    if args.trace:
        n_ev = tracer.write_chrome(args.trace)
        tracer.write_jsonl(args.trace + "l")  # FILE.json -> FILE.jsonl
        print(f"trace ({n_ev} events) -> {args.trace} "
              f"(Perfetto/chrome://tracing) + {args.trace}l")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
