"""Recompute roofline fields from the SAVED optimized HLO (no recompile).

Every dry-run cell persists its analysis-mode HLO under
``experiments/dryrun/<mesh>/hlo/<tag>.hlo.gz``; when the parsers in
hlo_stats / corrections / analytic evolve, this re-derives the JSON fields
in seconds instead of re-running hour-long compiles.

    PYTHONPATH=src python -m repro.launch.reroof [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import gzip
import json
import os


def reroof_cell(json_path: str, hlo_path: str) -> bool:
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.launch import analytic, corrections as corr, hlo_stats
    from repro.models import api

    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok" or rec.get("kind") == "bfs":
        return False
    cfg = dataclasses.replace(configs.get_config(rec["arch"]), scan_unroll=True)
    shape = SHAPES[rec["shape"]]
    chips = rec["chips"]
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    cstats = hlo_stats.collective_stats(hlo)
    wire_b = sum(v["wire_bytes"] for v in cstats.values())
    c = corr.prefill_corrections(cfg, shape)
    flops_dev = hlo_stats.dot_flops(hlo) + c["flops"] / chips
    bytes_dev = analytic.step_bytes(cfg, shape)["global"] / chips
    t_compute = flops_dev / hlo_stats.PEAK_FLOPS
    t_memory = bytes_dev / hlo_stats.HBM_BW
    t_coll = wire_b / hlo_stats.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    step_time = max(terms.values())
    mf = api.model_flops(cfg, shape)
    rec.update(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_wire_bytes=wire_b,
        collective_operand_bytes=sum(v["operand_bytes"] for v in cstats.values()),
        collectives=cstats,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        dominant=max(terms, key=terms.get),
        step_time_est=step_time,
        model_flops=mf,
        useful_flops_ratio=mf / (flops_dev * chips) if flops_dev else 0.0,
        roofline_fraction=(mf / chips / hlo_stats.PEAK_FLOPS) / step_time
        if step_time > 0 else 0.0,
    )
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    n = 0
    for mesh in ("single", "multi"):
        for jp in glob.glob(os.path.join(args.dir, mesh, "*.json")):
            tag = os.path.splitext(os.path.basename(jp))[0]
            hp = os.path.join(args.dir, mesh, "hlo", f"{tag}.hlo.gz")
            if os.path.exists(hp) and reroof_cell(jp, hp):
                n += 1
    print(f"re-derived roofline fields for {n} cells")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
