"""Analytic HBM-traffic model for the roofline memory term.

``cost_analysis()['bytes accessed']`` on the CPU backend counts
fusion-internal tensors at face value (including convert-before-slice
artifacts measured at 15-30× the real traffic — DESIGN.md §10), so the
memory term uses this documented first-principles model instead; the raw
HLO number is still recorded per cell as ``bytes_per_device_raw``.

Model (global bytes per step, divided by chips):

  train:   read params + write grads + read+write optimizer moments
           + write params + activation stream: per layer, the saved
           residual (B·L·d, bf16) is written in fwd and re-read in bwd,
           and the remat recompute re-reads the layer params once more;
           plus the attention KV / score traffic and the logits chunk.
  prefill: read params + write KV cache + activation stream (fwd only).
  decode:  read params + read whole KV cache + write one token slot
           (SSM: read+write the recurrent state instead).

All terms are exact sizes from the config — no fudge factors except the
activation stream's ×2 for intermediate ops inside a block (documented).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api

_BF16 = 2
_F32 = 4


def _param_bytes(cfg: ModelConfig) -> float:
    n = api.param_counts(cfg)["total"]
    return float(n) * (_BF16 if cfg.param_dtype == "bfloat16" else _F32)


def _active_param_bytes(cfg: ModelConfig) -> float:
    """Bytes of params actually TOUCHED per step (MoE: top-k experts only
    for compute, but the optimizer still touches all — handled by caller)."""
    n = api.param_counts(cfg)["active"] + api.param_counts(cfg)["embed"]
    return float(n) * (_BF16 if cfg.param_dtype == "bfloat16" else _F32)


def _opt_state_bytes(cfg: ModelConfig) -> float:
    n = api.param_counts(cfg)["total"]
    if cfg.optimizer == "adafactor":
        return float(n) * 0.02 * _F32  # factored: ~ (rows+cols)/(rows*cols)
    return float(n) * 2 * _F32  # adam m + v


def _kv_cache_bytes(cfg: ModelConfig, batch: int, s: int) -> float:
    if cfg.family == "ssm":
        state = cfg.n_layers * batch * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * _F32
            + (cfg.ssm_conv_width - 1)
            * (cfg.d_inner + 2 * cfg.ssm_state) * _BF16
        )
        return float(state)
    per_entry = cfg.n_kv_heads * cfg.resolved_head_dim * 2 * _BF16
    kv = 0.0
    for i in range(cfg.n_layers):
        if not cfg.is_attn_layer(i):
            continue
        s_i = s
        if cfg.ring_local_cache and not cfg.is_global_attn_layer(i):
            s_i = min(s, cfg.local_window)  # §Perf: ring local cache
        kv += batch * s_i * per_entry
    if cfg.family == "hybrid":
        n_mamba = sum(
            1 for i in range(cfg.n_layers) if not cfg.is_attn_layer(i)
        )
        kv += n_mamba * batch * (
            cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * _F32
        )
    if cfg.family == "audio":
        kv += cfg.n_layers * batch * cfg.n_frames * cfg.n_kv_heads \
            * cfg.resolved_head_dim * 2 * _BF16
    return float(kv)


def _act_stream_bytes(cfg: ModelConfig, batch: int, l: int, train: bool) -> float:
    d = cfg.d_model
    per_layer = batch * l * d * _BF16
    layers_total = cfg.n_layers + cfg.encoder_layers
    # write residual fwd (+ read in bwd) + ~2 intermediate r/w inside block
    mult = (2 + 4) if train else 3
    return float(layers_total) * per_layer * mult


def step_bytes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    b, l = shape.global_batch, shape.seq_len
    p = _param_bytes(cfg)
    pa = _active_param_bytes(cfg)
    if shape.kind == "train":
        opt = _opt_state_bytes(cfg)
        total = (
            pa  # fwd reads active params
            + pa  # remat recompute reads them again in bwd
            + p  # grads written (all params get grads)
            + p  # params written
            + 2 * opt  # moments read + write
            + _act_stream_bytes(cfg, b, l, train=True)
        )
    elif shape.kind == "prefill":
        total = pa + _kv_cache_bytes(cfg, b, l) + _act_stream_bytes(cfg, b, l, False)
    else:  # decode
        extra = cfg.n_patches if cfg.family == "vlm" else 0
        total = pa + _kv_cache_bytes(cfg, b, l + extra) + b * cfg.d_model * 400
    return {"global": total, "detail": {"params": p, "active": pa}}
