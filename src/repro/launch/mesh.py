"""Production mesh builders (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* importing
jax; everything else (tests, benchmarks) sees the real device count.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist, as a 1-D data mesh (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
