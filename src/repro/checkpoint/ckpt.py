"""Checkpointing: mesh-agnostic manifests + async save + elastic restore.

Design for 1000+ nodes (DESIGN.md §11):

* **Mesh-agnostic layout**: leaves are stored as full logical arrays keyed
  by their pytree path, with a JSON manifest (step, config name, tree
  structure).  Restore reshards onto WHATEVER mesh the new job runs — the
  elastic-scaling requirement (checkpoints outlive the cluster shape).
* **Async save**: arrays are snapshotted to host (one blocking device→host
  copy), then serialization runs on a writer thread — the train loop only
  stalls for the copy, not the disk write.
* **Atomicity**: writes go to ``<dir>.tmp`` then ``os.replace`` — a
  crash mid-save never corrupts the latest checkpoint (restart safety).
* On a real multi-host pod each host writes its own data-parallel shard
  manifest; this container is single-process so the write is one file.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree.flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(path: str, step: int, trees: Dict[str, Any], *, async_: bool = False,
         meta: Optional[Dict] = None) -> Optional[threading.Thread]:
    """trees: named pytrees, e.g. {"params": ..., "opt_state": ...}."""
    host: Dict[str, np.ndarray] = {}
    treedefs = {}
    for name, tree in trees.items():
        flat = _flatten(tree)
        for k, v in flat.items():
            host[f"{name}/{k}"] = np.asarray(v)  # device -> host (blocking)
        treedefs[name] = jax.tree.structure(tree)

    def write():
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host.keys()),
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(path):
            os.replace(os.path.join(tmp, "arrays.npz"), os.path.join(path, "arrays.npz"))
            os.replace(os.path.join(tmp, "manifest.json"), os.path.join(path, "manifest.json"))
            os.rmdir(tmp)
        else:
            os.replace(tmp, path)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(path: str) -> Optional[int]:
    man = os.path.join(path, "manifest.json")
    if not os.path.exists(man):
        return None
    with open(man) as f:
        return json.load(f)["step"]


def restore(path: str, templates: Dict[str, Any], *, mesh=None, pspecs=None
            ) -> Tuple[int, Dict[str, Any]]:
    """Restore named pytrees; ``templates`` provide the tree structure.

    When ``mesh``/``pspecs`` (matching named trees of PartitionSpec) are
    given, leaves are device_put with those shardings — the **elastic
    reshard**: the stored full arrays are placed onto the new mesh no
    matter what mesh wrote them.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    out = {}
    for name, template in templates.items():
        flat = _flatten(template)
        restored = {}
        for k in flat:
            restored[k] = data[f"{name}/{k}"]
        leaves_order = list(_flatten(template).keys())
        new_leaves = [restored[k] for k in leaves_order]
        tdef = jax.tree.structure(template)
        tree = jax.tree.unflatten(tdef, new_leaves)
        if mesh is not None and pspecs is not None and name in pspecs:
            from jax.sharding import NamedSharding

            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                tree,
                pspecs[name],
            )
        out[name] = tree
    return manifest["step"], out
